"""Structural HLO analysis for the roofline report.

``compiled.cost_analysis()`` reports FLOPs/bytes for a single execution of
each computation — it does NOT multiply ``while`` bodies by their trip
count, so a scan-over-layers model under-reports by ~n_layers x.  And it
reports no collective traffic at all.  This module parses the optimized
(post-SPMD) HLO text instead:

* splits the module into computations, builds the call graph
  (``while`` bodies/conds, ``calls=``/``to_apply=``, conditional branches)
  and propagates loop multipliers — trip counts come from the while op's
  ``backend_config known_trip_count`` (present for scan-derived loops),
  falling back to the largest constant in the condition computation;
* **FLOPs**: every ``dot``/``convolution`` op anywhere in the graph:
  ``2 * prod(result_dims) * prod(contracting_dims)`` x multiplier
  (per-device numbers, since post-SPMD shapes are shard shapes);
* **memory bytes**: per-op operand+result bytes, counted only at
  "top-level" computations (entry + loop bodies) so fusion interiors are
  not double-counted;
* **collectives**: result bytes per op with a ring cost model.

Per-op collective time on a ring of n devices with per-link bandwidth B:
    all-gather / reduce-scatter / all-to-all   t = bytes * (n-1)/n / B
    all-reduce                                 t = 2 * bytes * (n-1)/n / B
    collective-permute                         t = bytes / B
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|bf16|f16|[suf]\d+|c64|c128)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_OPLINE_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(")


def _shape_dims(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(text):
        out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


def shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _replica_group_size(line: str) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    return 2


@dataclasses.dataclass
class Op:
    name: str
    comp: str
    kind: str          # opcode-ish
    line: str
    result_shape: str  # text before opcode


class HloModule:
    def __init__(self, hlo: str):
        self.comps: dict[str, list[Op]] = {}
        self.entry = None
        self.op_shape: dict[str, str] = {}   # op name -> result shape text
        cur = None
        for raw in hlo.splitlines():
            line = raw.rstrip()
            s = line.strip()
            if s.endswith("{") and ("->" in s or s.startswith("ENTRY")):
                m = _HEADER_RE.match(s)
                if m:
                    cur = m.group(1)
                    self.comps[cur] = []
                    if s.startswith("ENTRY"):
                        self.entry = cur
                continue
            if s == "}":
                cur = None
                continue
            if cur is None:
                continue
            m = _OPLINE_RE.match(s)
            if not m:
                continue
            name, rest = m.group(1), m.group(2)
            # result shape is either a (possibly huge) tuple — no nested
            # parens inside — or a single array literal
            om = re.match(r"((?:\([^()]*\))|(?:[\w\[\],\{\}\d]+))\s+([\w\-]+)\(",
                          rest)
            if om:
                rshape, opcode = om.group(1), om.group(2)
            else:
                rshape, opcode = rest, "unknown"
            op = Op(name, cur, opcode, s, rshape)
            self.comps[cur].append(op)
            self.op_shape[name] = rshape
        # parameters: register their shapes too
        for comp, ops in self.comps.items():
            for op in ops:
                if op.kind == "parameter":
                    self.op_shape[op.name] = op.result_shape

    # -- call graph -----------------------------------------------------------
    def _edges(self, comp: str):
        """(callee, multiplier, via_loop) triples."""
        out = []
        for op in self.comps.get(comp, ()):
            mw = re.search(r"while\(.*?\), condition=%?([\w\.\-]+), "
                           r"body=%?([\w\.\-]+)", op.line)
            if mw:
                tc = self._trip_count(op.line, mw.group(1))
                out.append((mw.group(2), tc, True))
                out.append((mw.group(1), tc, True))
                continue
            for mm in re.finditer(
                    r"(?:calls=|to_apply=)%?([\w\.\-]+)", op.line):
                out.append((mm.group(1), 1, False))
            mb = re.search(r"branch_computations=\{([^}]*)\}", op.line)
            if mb:
                for b in mb.group(1).split(","):
                    out.append((b.strip().lstrip("%"), 1, False))
        return out

    def _trip_count(self, while_line: str, cond: str) -> int:
        m = re.search(r'known_trip_count[^0-9]*(\d+)', while_line)
        if m:
            return int(m.group(1))
        best = 1
        for op in self.comps.get(cond, ()):
            for c in re.findall(r"constant\((\d+)\)", op.line):
                best = max(best, int(c))
        return best

    def multipliers(self) -> tuple[dict[str, int], dict[str, bool]]:
        """comp -> execution count; comp -> reached-only-via-call flag."""
        mult = {self.entry: 1}
        via_call: dict[str, bool] = {self.entry: False}
        stack = [self.entry]
        seen = set()
        while stack:
            name = stack.pop()
            for callee, k, is_loop in self._edges(name):
                key = (name, callee)
                if key in seen or callee not in self.comps:
                    continue
                seen.add(key)
                mult[callee] = max(mult.get(callee, 0), mult[name] * k)
                vc = via_call.get(name, False) or not is_loop
                via_call[callee] = via_call.get(callee, True) and vc
                stack.append(callee)
        return mult, via_call

    # -- metrics ----------------------------------------------------------------
    def _operand_names(self, op: Op) -> list[str]:
        """Operand names of ``op``.

        Compiled-HLO text prints operands with their shapes and possibly
        tuple-typed (nested-paren) annotations::

            dot(f32[4,64]{1,0} %copy.1, f32[64,16]{1,0} %all-gather.1)
            while((s32[], f32[4,16]{1,0}) %tuple.2), condition=...

        so scan to the *balanced* closing paren and pull every ``%name``
        token — trailing attributes (metadata, to_apply) sit outside it.
        """
        start = op.line.find(op.kind + "(")
        if start < 0:
            return []
        i = start + len(op.kind)
        depth = 0
        for j in range(i, len(op.line)):
            if op.line[j] == "(":
                depth += 1
            elif op.line[j] == ")":
                depth -= 1
                if depth == 0:
                    return re.findall(r"%([\w\.\-]+)", op.line[i:j])
        return re.findall(r"%([\w\.\-]+)", op.line[i:])

    def dot_flops(self, op: Op) -> float:
        """2 * prod(result) * prod(contracting dims of lhs)."""
        res = _shape_dims(op.result_shape)
        if not res:
            return 0.0
        out_elems = 1
        for d in res[0][1]:
            out_elems *= d
        k = 1
        mc = re.search(r"lhs_contracting_dims=\{([^}]*)\}", op.line)
        ops_names = self._operand_names(op)
        if mc and ops_names:
            lhs_shape = _shape_dims(self.op_shape.get(ops_names[0], ""))
            if lhs_shape:
                dims = lhs_shape[0][1]
                for ci in mc.group(1).split(","):
                    ci = ci.strip()
                    if ci and int(ci) < len(dims):
                        k *= dims[int(ci)]
        return 2.0 * out_elems * k

    def conv_flops(self, op: Op) -> float:
        res = _shape_dims(op.result_shape)
        if not res:
            return 0.0
        out_elems = 1
        for d in res[0][1]:
            out_elems *= d
        names = self._operand_names(op)
        k = 1
        if len(names) >= 2:
            ker = _shape_dims(self.op_shape.get(names[1], ""))
            if ker:
                for d in ker[0][1][:-1]:  # all but output-feature dim
                    k *= d
        return 2.0 * out_elems * k

    _FREE_OPS = frozenset({
        "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
        "while", "conditional", "call", "after-all", "partition-id",
        "replica-id", "domain",
    })
    _SLICE_OPS = frozenset({"dynamic-slice", "slice", "gather"})
    _UPDATE_OPS = frozenset({"dynamic-update-slice", "scatter"})

    def _op_traffic(self, op: Op) -> float:
        """Approximate HBM bytes moved by one execution of ``op``.

        Real-hardware model: slices/gathers touch only the slice (not the
        sliced operand); in-place updates touch only the update; bitcasts
        and control ops are free; everything else reads its operands and
        writes its result.  This is an HBM-traffic *estimate* — fusion on
        the real TPU backend differs from the CPU HLO analyzed here
        (documented in EXPERIMENTS.md §Roofline).
        """
        kind = op.kind
        if kind in self._FREE_OPS:
            return 0.0
        if kind in self._SLICE_OPS:
            return 2.0 * shape_bytes(op.result_shape)
        if kind in self._UPDATE_OPS:
            names = self._operand_names(op)
            upd = shape_bytes(self.op_shape.get(names[1], "")) \
                if len(names) > 1 else 0
            return 2.0 * upd
        if kind in ("broadcast", "iota", "reshape", "transpose", "copy"):
            return shape_bytes(op.result_shape) * (2.0 if kind in
                                                   ("transpose", "copy")
                                                   else 1.0)
        if kind == "sort":
            # TPU sorts are multi-pass networks (~bitonic): charge
            # log2(n)(log2(n)+1)/2 read+write sweeps, not one.
            import math
            b = shape_bytes(op.result_shape)
            dims = _shape_dims(op.result_shape)
            n = max((max(d[1], default=1) for d in dims), default=1)
            if isinstance(n, list):
                n = max(n, default=1)
            lg = max(1, math.ceil(math.log2(max(2, n))))
            return 2.0 * b * lg * (lg + 1) / 2
        b = shape_bytes(op.result_shape)
        for on in self._operand_names(op):
            b += shape_bytes(self.op_shape.get(on, ""))
        return float(b)

    def analyze(self, link_bw: float = 50e9) -> dict:
        mult, via_call = self.multipliers()
        flops = 0.0
        mem_bytes = 0.0
        coll: dict[str, dict] = {}
        for comp, ops in self.comps.items():
            m = mult.get(comp, 0)
            if m == 0:
                continue
            top_level = not via_call.get(comp, True) or comp == self.entry
            for op in ops:
                if op.kind in ("dot",):
                    flops += m * self.dot_flops(op)
                elif op.kind in ("convolution",):
                    flops += m * self.conv_flops(op)
                if top_level:
                    mem_bytes += m * self._op_traffic(op)
                for kind in _COLLECTIVES:
                    if op.kind == kind or op.kind == kind + "-start":
                        n = max(2, _replica_group_size(op.line))
                        bts = shape_bytes(op.result_shape)
                        f = (n - 1) / n
                        per = {"all-reduce": 2 * f, "all-gather": f,
                               "reduce-scatter": f, "all-to-all": f,
                               "collective-permute": 1.0}[kind]
                        d = coll.setdefault(kind, {"count": 0, "bytes": 0.0,
                                                   "time_s": 0.0})
                        d["count"] += m
                        d["bytes"] += m * bts
                        d["time_s"] += m * bts * per / link_bw
                        break
        return {
            "flops_per_device": flops,
            "mem_bytes_per_device": mem_bytes,
            "collectives": coll,
            "collective_bytes": sum(d["bytes"] for d in coll.values()),
            "collective_time_s": sum(d["time_s"] for d in coll.values()),
        }


def analyze_hlo(hlo: str, link_bw: float = 50e9) -> dict:
    return HloModule(hlo).analyze(link_bw)
