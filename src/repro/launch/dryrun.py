"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set the 512-host-device flag before ANY other import (jax locks the
device count on first init) — hence the first two lines.

For each cell the driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. builds the arch's step function (train_step for train shapes,
     prefill/serve_step for inference shapes) with sharded abstract
     inputs (ShapeDtypeStruct — no allocation),
  3. ``.lower().compile()`` — failures here are sharding bugs,
  4. records memory_analysis / cost_analysis / structural HLO roofline
     terms into a JSON artifact consumed by benchmarks/roofline.py and
     EXPERIMENTS.md.

The paper's own technique is dry-run as the ``hiperfact-closure`` cell:
the distributed semi-naive closure step (core/distributed.py) lowered on
the same meshes.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --mesh single --arch qwen2-7b
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi            # all
"""

import os

os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_EXTRA", "")
                           + " --xla_force_host_platform_device_count=512")

# ruff: noqa: E402
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.distributed.sharding import (activation_hints, batch_shardings,
                                        sharded_abstract)
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.models import SHAPES, applicable_shapes, build_model
from repro.models.config import ShapeConfig
from repro.models.model_api import (decode_input_specs, model_cache_spec,
                                    prefill_input_specs, train_input_specs)
from repro.models.params import LeafSpec, is_leaf_spec
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import build_train_step

# v5e-class hardware constants (roofline denominators)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link


def _serve_spec(spec_tree):
    """Serving params: float leaves stored bf16."""
    def one(s: LeafSpec):
        dt = "bfloat16" if s.dtype in ("float32", "bfloat16") else s.dtype
        return LeafSpec(s.shape, s.axes, s.init, s.scale, dt)
    return jax.tree.map(one, spec_tree, is_leaf=is_leaf_spec)


def build_cell(arch: str, shape_name: str, mesh, pure_shapes: bool = False):
    """-> (jitted_fn, example_args (abstract), meta)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    kind = shape.kind
    hints = activation_hints(cfg, mesh, shape.global_batch,
                             "train" if kind == "train" else
                             ("prefill" if kind == "prefill" else "decode"))
    model = build_model(cfg, hints)

    if kind == "train":
        spec = model.spec()
        params = sharded_abstract(spec, mesh)
        opt_shardings = jax.tree.map(lambda x: x, params)
        state = {
            "params": params,
            "opt": {
                "m": params, "v": params,
                "step": jax.ShapeDtypeStruct((), jnp.int32),
            },
        }
        inputs = train_input_specs(cfg, shape)
        bsh = batch_shardings(inputs, mesh, shape.global_batch)
        batch = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            inputs, bsh)
        accum = cfg.accum_for.get(shape_name, 1)
        step = build_train_step(model, OptimizerConfig(), accum)
        fn = jax.jit(step, donate_argnums=(0,))
        args = (state, batch)
    elif kind == "prefill":
        spec = _serve_spec(model.spec())
        params = sharded_abstract(spec, mesh)
        inputs = prefill_input_specs(cfg, shape)
        bsh = batch_shardings(inputs, mesh, shape.global_batch)
        batch = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            inputs, bsh)

        def prefill_step(params, batch):
            toks = batch["tokens"]
            fk = {k: v for k, v in batch.items() if k != "tokens"}
            return model.prefill_fn(params, toks, shape.seq_len, **fk)

        # constrain the OUTPUT cache sharding (batch->data, seq->model):
        # without this XLA infers a model-replicated cache (mistral
        # prefill: 22 GB/device of output vs 1.5 GB sharded)
        from repro.distributed.sharding import shardings_for
        cspec = model_cache_spec(cfg, shape.global_batch, shape.seq_len)
        cache_sh = shardings_for(cspec, mesh)
        fn = jax.jit(prefill_step, out_shardings=(None, cache_sh))
        args = (params, batch)
    else:  # decode
        spec = _serve_spec(model.spec())
        params = sharded_abstract(spec, mesh)
        cspec = model_cache_spec(cfg, shape.global_batch, shape.seq_len)
        cache = sharded_abstract(cspec, mesh)
        tok_sh = batch_shardings(
            {"tok": jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)},
            mesh, shape.global_batch)["tok"]
        tok = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32,
                                   sharding=tok_sh)
        fn = jax.jit(model.decode_fn, donate_argnums=(2,))
        args = (params, tok, cache)
    return fn, args, {"arch": arch, "shape": shape_name, "kind": kind,
                      "params": cfg.param_count(),
                      "active_params": cfg.active_param_count()}


def build_closure_cell(mesh):
    """The paper's technique at pod scale: one semi-naive closure step."""
    from repro.core.distributed import ClosureConfig, closure_step
    import functools
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P

    ccfg = ClosureConfig(edge_cap=1 << 16, delta_cap=1 << 14,
                         slot_cap=1 << 7, join_cap=1 << 15)
    axis_names = tuple(mesh.axis_names)
    n_dev = int(np.prod(mesh.devices.shape))
    spec = P(axis_names)
    step = functools.partial(closure_step, cfg=ccfg, axis_names=axis_names,
                             n_dev=n_dev)
    keys = ("edges", "closure", "delta", "fresh", "overflow")
    fn = jax.jit(shard_map(step, mesh=mesh,
                           in_specs=({k: spec for k in keys},),
                           out_specs={k: spec for k in keys},
                           check_rep=False))
    sh = NamedSharding(mesh, spec)
    state = {
        "edges": jax.ShapeDtypeStruct((n_dev * ccfg.edge_cap,), jnp.int64,
                                      sharding=sh),
        "closure": jax.ShapeDtypeStruct((n_dev * ccfg.edge_cap,), jnp.int64,
                                        sharding=sh),
        "delta": jax.ShapeDtypeStruct((n_dev * ccfg.delta_cap,), jnp.int64,
                                      sharding=sh),
        "fresh": jax.ShapeDtypeStruct((n_dev,), jnp.int64, sharding=sh),
        "overflow": jax.ShapeDtypeStruct((n_dev,), jnp.int64, sharding=sh),
    }
    return fn, (state,), {"arch": "hiperfact-closure", "shape": "closure_64k",
                          "kind": "infer", "params": 0, "active_params": 0}


def run_cell(fn, args, meta, mesh, out_dir: str, tag: str) -> dict:
    n_dev = int(np.prod(mesh.devices.shape))
    t0 = time.perf_counter()
    lowered = fn.lower(*args)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    rec = dict(meta)
    rec["mesh"] = {"shape": list(mesh.devices.shape),
                   "axes": list(mesh.axis_names), "devices": n_dev}
    rec["lower_s"] = round(t_lower, 2)
    rec["compile_s"] = round(t_compile, 2)

    ma = compiled.memory_analysis()
    if ma is not None:
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes_per_device": int(ma.argument_size_in_bytes
                                         + ma.temp_size_in_bytes
                                         + ma.output_size_in_bytes
                                         - ma.alias_size_in_bytes),
        }
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                            if isinstance(v, (int, float))
                            and k in ("flops", "bytes accessed",
                                      "transcendentals")}
    hlo = compiled.as_text()
    rec["hlo_bytes"] = len(hlo)
    h = analyze_hlo(hlo, LINK_BW)
    rec["hlo"] = {
        "flops_per_device": h["flops_per_device"],
        "mem_bytes_per_device": h["mem_bytes_per_device"],
        "collective_bytes": h["collective_bytes"],
        "collectives": h["collectives"],
    }
    # roofline terms (seconds)
    rec["roofline"] = {
        "compute_s": h["flops_per_device"] / PEAK_FLOPS,
        "memory_s": h["mem_bytes_per_device"] / HBM_BW,
        "collective_s": h["collective_time_s"],
    }
    terms = rec["roofline"]
    rec["bottleneck"] = max(terms, key=terms.get)

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id, 'all', or 'hiperfact'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default="out/dryrun")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    out_dir = os.path.join(args.out, args.mesh)

    cells: list[tuple[str, str]] = []
    arch_list = ARCH_NAMES if args.arch == "all" else (
        [] if args.arch == "hiperfact" else [args.arch])
    for a in arch_list:
        cfg = get_config(a)
        shapes = applicable_shapes(cfg) if args.shape == "all" \
            else [args.shape]
        for s in shapes:
            if s not in applicable_shapes(cfg):
                print(f"SKIP {a} x {s}: inapplicable "
                      "(full-attention arch at 500k — DESIGN.md §4)")
                continue
            cells.append((a, s))

    results = []
    for a, s in cells:
        tag = f"{a}__{s}"
        print(f"=== {tag} [{args.mesh}] ===", flush=True)
        try:
            fn, fargs, meta = build_cell(a, s, mesh)
            rec = run_cell(fn, fargs, meta, mesh, out_dir, tag)
            print(f"  ok: compile {rec['compile_s']}s  "
                  f"peak/dev {rec.get('memory', {}).get('peak_bytes_per_device', 0)/2**30:.2f} GiB  "
                  f"bottleneck {rec['bottleneck']}", flush=True)
            results.append((tag, "ok"))
        except Exception as e:  # noqa: BLE001 — report, continue matrix
            traceback.print_exc()
            results.append((tag, f"FAIL {e}"))

    if args.arch in ("all", "hiperfact"):
        tag = "hiperfact-closure"
        print(f"=== {tag} [{args.mesh}] ===", flush=True)
        try:
            fn, fargs, meta = build_closure_cell(mesh)
            rec = run_cell(fn, fargs, meta, mesh, out_dir, tag)
            print(f"  ok: compile {rec['compile_s']}s  "
                  f"bottleneck {rec['bottleneck']}", flush=True)
            results.append((tag, "ok"))
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            results.append((tag, f"FAIL {e}"))

    print("\n==== dry-run summary ====")
    fails = 0
    for tag, status in results:
        print(f"{status:6s} {tag}" if status == "ok" else f"{status}  {tag}")
        fails += status != "ok"
    print(f"{len(results) - fails}/{len(results)} cells passed")
    raise SystemExit(1 if fails else 0)


if __name__ == "__main__":
    main()
