"""Training launcher.

Examples::

    # tiny smoke run on CPU
    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke --steps 30

    # 8-host-device distributed run (2x4 mesh, FSDP+TP)
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \\
        --mesh 2x4 --steps 20 --ckpt-dir /tmp/ckpt

On a real TPU fleet the same entry point runs under the production mesh
(launch/mesh.py); fault tolerance: every run resumes from the latest
committed checkpoint automatically (see runtime/monitor.py for the
supervisor policy).
"""

from __future__ import annotations

import argparse
import logging


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", default=None,
                    help="AxB data x model mesh over available devices")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--data", default="synthetic",
                    choices=["synthetic", "facts"])
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    import jax
    from repro.configs import get_config
    from repro.data import DataConfig, ShardedLoader, SyntheticLM
    from repro.train import OptimizerConfig, Trainer, TrainerConfig

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = None
    if args.mesh:
        a, b = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((a, b), ("data", "model"))

    if args.data == "facts":
        from repro.data.factsource import FactCorpusSource
        src = FactCorpusSource(cfg.vocab, args.seq, args.batch)
    else:
        src = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                     global_batch=args.batch))
    loader = ShardedLoader(src)
    trainer = Trainer(
        cfg, loader,
        OptimizerConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                        total_steps=args.steps),
        TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                      accum=args.accum),
        mesh=mesh, global_batch=args.batch)
    _, losses = trainer.run()
    print(f"final loss: {losses[-1]:.4f} (start {losses[0]:.4f})")


if __name__ == "__main__":
    main()
