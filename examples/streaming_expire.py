"""Streaming expiry: append -> infer -> bulk-expire -> re-infer.

    PYTHONPATH=src python examples/streaming_expire.py [--backend B]
                                                       [--rounds N]
                                                       [--shards S]

The retraction-shaped workload the signed delta frontiers target: an
IoT fleet streams sensor readings in, a two-hop rule chain raises and
routes alerts, and every round the previous window of readings expires
wholesale (TTL).  Three layers keep the per-round cost proportional to
the *change* (Δ), not the store (N) — each is printed per round:

* **signed frontiers** (`eval_mode="delta"`, the default under "auto"):
  every `(rule, fact-type)` pass sees an O(Δ) window of +rows *and*
  -rows; deletions run negative inclusion–exclusion passes
  (`neg_passes`) over the delete log instead of re-evaluating the rule
  (`full_evals` stays 0 after warm-up);
* **counting support**: derived facts carry support counters, so a
  retraction only kills a fact whose last derivation died
  (`facts_retracted`), and deleting an asserted fact that is still
  derived elsewhere merely clears the assertion bit
  (`compensated_deletes`) — no churn, no index rebuilds;
* **bounded tombstones** (device backends): dead rows ride inside the
  sorted index mirrors until they exceed a quarter of the alive rows,
  so expiry does not trigger per-round mirror rebuilds.

Recursive rules are the one case counting cannot localize; those fall
back to a DRed overdelete/rederive scrub (`dred_scrubs`) — this
workload has none, so the counter stays 0.
"""

from __future__ import annotations

import argparse

from repro.core import EngineConfig, Fact, HiperfactEngine, Rule
from repro.core.conditions import AddAction, cond, term


def make_rules() -> list[Rule]:
    return [
        Rule("hot",
             (cond("Reading", "?s", "temp", "?t"),
              cond("Threshold", "?t", "class", "hot")),
             (AddAction("Alert", term("?s"), "level", "hot"),)),
        Rule("zone-alert",
             (cond("Alert", "?s", "level", "hot"),
              cond("Zone", "?s", "in", "?z")),
             (AddAction("ZoneAlert", term("?z"), "has", term("?s")),)),
        Rule("audit",
             (cond("ZoneAlert", "?z", "has", "?s"),),
             (AddAction("Audit", term("?z"), "saw", term("?s")),)),
    ]


def window(r: int, n_sensors: int) -> tuple[list[Fact], list[Fact]]:
    """One round's readings + zone memberships for a fresh sensor id
    range (sensor ids never repeat: this is a stream, not an update)."""
    base = r * n_sensors
    readings = [Fact("Reading", f"s{base + i}", "temp", f"t{i % 7}")
                for i in range(n_sensors)]
    zones = [Fact("Zone", f"s{base + i}", "in", f"z{i % 4}")
             for i in range(n_sensors)]
    return readings, zones


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="numpy",
                    choices=["numpy", "jax", "jax-pallas", "jax-interpret"])
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--sensors", type=int, default=200,
                    help="window size (CI smoke uses a small one)")
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--eval-mode", default="delta",
                    choices=["auto", "delta", "full"])
    args = ap.parse_args()

    import dataclasses
    cfg = dataclasses.replace(EngineConfig.infer1(args.backend),
                              eval_mode=args.eval_mode, shards=args.shards)
    engine = HiperfactEngine(cfg)
    engine.add_rules(make_rules())
    engine.insert_facts([Fact("Threshold", f"t{k}", "class", "hot")
                         for k in (5, 6)])
    engine.infer()

    prev: list[Fact] | None = None
    for r in range(args.rounds):
        readings, zones = window(r, args.sensors)
        engine.insert_facts(readings + zones)
        sa = engine.infer()
        line = (f"round {r}: append infer {sa.seconds:.3f}s "
                f"+{sa.facts_inferred} facts "
                f"delta_passes={sa.delta_passes} "
                f"full_evals={sa.full_evals}")
        if prev is not None:
            engine.delete_facts(prev)
            sd = engine.infer()
            line += (f" | expire infer {sd.seconds:.3f}s "
                     f"-{sd.facts_retracted + sd.facts_deleted} facts "
                     f"neg_passes={sd.neg_passes} "
                     f"full_evals={sd.full_evals} "
                     f"compensated={sd.compensated_deletes} "
                     f"scrubs={sd.dred_scrubs}")
            if r > 1 and args.eval_mode != "full":
                # steady state: retraction is delta work, never a rescan
                assert sd.full_evals == 0, sd.full_evals
        prev = readings
        print(line)

    # only the newest window's alerts survive expiry
    n = (engine.num_facts() if args.shards > 1
         else engine.store.num_facts())
    alerts = engine.query([cond("Alert", "?s", "level", "hot")])
    hot_per_window = sum(1 for i in range(args.sensors) if i % 7 in (5, 6))
    print(f"done: {n} facts resident; {len(alerts)} live alerts "
          f"(one window's worth = {hot_per_window})")
    assert len(alerts) == hot_per_window


if __name__ == "__main__":
    main()
