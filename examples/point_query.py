"""Cold-store point query: demand-driven evaluation end to end.

    PYTHONPATH=src python examples/point_query.py [--backend B]
                                                  [--chains K] [--hops L]
                                                  [--shards S]

The serving-shaped workload the demand transformation targets: a store
is loaded and recursive rules are registered, but nothing is inferred —
then a point query arrives.  Under ``eval_mode="full"`` the engine
would have to materialize the whole closure (every chain's paths)
before it can answer; under ``eval_mode="demand"`` the query constants
seed per-type demand frontiers, restriction propagates backward through
the producing rules, and only the *queried* chain's cone is evaluated:

* ``demand_cone_rows`` — facts materialized for the cone (O(L²) for one
  chain, independent of how many chains are resident);
* ``rows_considered`` — join input rows actually touched, a small
  fraction of the full closure's;
* the sketch planner (``sort_mode="sketch"``) orders the joins from
  device-computed cardinality sketches, re-planning on 4x drift
  (``replans``);
* a re-query at unchanged table versions is a query-cache hit — no
  evaluation, no transfers, one row copy.

Results are checksum-identical to full evaluation (asserted below by
running both).
"""

from __future__ import annotations

import argparse

from repro.core import EngineConfig, Fact, HiperfactEngine, Rule
from repro.core.conditions import AddAction, cond, term


def make_rules() -> list[Rule]:
    """Transitive closure: path = edge | edge . path."""
    return [
        Rule("base", (cond("edge", "?x", "to", "?y"),),
             (AddAction("path", term("?x"), "to", term("?y")),)),
        Rule("rec", (cond("edge", "?x", "to", "?y"),
                     cond("path", "?y", "to", "?z")),
             (AddAction("path", term("?x"), "to", term("?z")),)),
    ]


def make_facts(chains: int, hops: int) -> list[Fact]:
    """K disjoint chains of L edges; only chain 0 will be queried."""
    return [Fact("edge", f"c{k}_n{i}", "to", f"c{k}_n{i + 1}")
            for k in range(chains) for i in range(hops)]


def row_set(rows: list[dict]) -> set:
    return {tuple(sorted(r.items())) for r in rows}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="numpy",
                    choices=["numpy", "jax", "jax-pallas", "jax-interpret"])
    ap.add_argument("--chains", type=int, default=12)
    ap.add_argument("--hops", type=int, default=12)
    ap.add_argument("--shards", type=int, default=1)
    args = ap.parse_args()

    import dataclasses
    query = [cond("path", "c0_n0", "to", "?z")]
    facts = make_facts(args.chains, args.hops)

    # -- demand engine: load + rules, NO infer() — the query drives it
    cfg = dataclasses.replace(EngineConfig.infer1(args.backend),
                              eval_mode="demand", sort_mode="sketch",
                              shards=args.shards)
    engine = HiperfactEngine(cfg)
    engine.add_rules(make_rules())
    engine.insert_facts(facts)
    rows = engine.query(query)
    st = engine.last_infer
    n = (engine.num_facts() if args.shards > 1
         else engine.store.num_facts())
    print(f"demand: {len(rows)} results from a cold store of "
          f"{len(facts)} edges ({args.chains} chains)")
    print(f"  cone_rows={st.demand_cone_rows} rounds={st.demand_rounds} "
          f"rows_considered={st.rows_considered} "
          f"fallbacks={st.demand_fallbacks} "
          f"sketch={st.sketch_hits}h/{st.sketch_misses}m "
          f"replans={st.replans}")
    # only the queried chain's cone was materialized
    assert n < len(facts) + args.chains * args.hops * (args.hops + 1) // 2
    assert st.demand_fallbacks == 0 and st.demand_cone_rows > 0

    # -- re-query at fixed versions: pure cache hit
    hits0 = engine.last_infer.query_cache_hits
    rows_again = engine.query(query)
    assert engine.last_infer.query_cache_hits == hits0 + 1
    assert row_set(rows_again) == row_set(rows)
    print(f"  re-query: cache hit, {len(rows_again)} rows, no evaluation")

    # -- full-closure comparator: same answers, much more work
    full = HiperfactEngine(dataclasses.replace(cfg, eval_mode="full",
                                               sort_mode="sortkeys"))
    full.add_rules(make_rules())
    full.insert_facts(facts)
    fs = full.infer()
    full_rows = full.query(query)
    print(f"full: inferred {fs.facts_inferred} facts to answer the same "
          f"query (rows_considered={full.last_infer.rows_considered})")
    assert row_set(full_rows) == row_set(rows), "demand ≠ full!"
    ratio = st.rows_considered / max(full.last_infer.rows_considered, 1)
    print(f"parity OK; demand touched {100 * ratio:.1f}% of full's rows")


if __name__ == "__main__":
    main()
