"""Quickstart: the Hiperfact engine in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Covers: facts (Def. 1), rules with computed actions (Def. 3), variable
join tests (Def. 9), inference to fixpoint, and ad-hoc queries.
"""

from repro.core import (EngineConfig, Fact, HiperfactEngine, Rule,
                        ValueType)
from repro.core.conditions import AddAction, cond, term


def main() -> None:
    engine = HiperfactEngine(EngineConfig.infer1())

    # -- the paper's running example: derive USD profits ------------------
    engine.add_rule(Rule(
        "usd-profit",
        conditions=(
            cond("DailySales", "?s", "profitEUR", "?p", ValueType.DOUBLE),
            cond("DailySales", "?s", "EURUSD", "?f", ValueType.DOUBLE),
        ),
        actions=(AddAction(
            "DailySales", term("?s"), "profitUSD", None, ValueType.DOUBLE,
            compute=lambda b: _mul(b["p"], b["f"])),),
    ))
    # -- age classification with a join test (Def. 9) ---------------------
    engine.add_rule(Rule(
        "age-class",
        conditions=(
            cond("AgeClass", "?ac", "minAge", "?m", ValueType.UINT32),
            cond("Person", "?x", "age", "?a", ValueType.UINT32,
                 tests=[("?a", ">=", "?m")]),
        ),
        actions=(AddAction("Person", term("?x"), "inClass", term("?ac")),),
    ))

    engine.insert_facts([
        Fact("DailySales", "s1", "profitEUR", 100.0, ValueType.DOUBLE),
        Fact("DailySales", "s1", "EURUSD", 1.1, ValueType.DOUBLE),
        Fact("AgeClass", "kid", "minAge", 0, ValueType.UINT32),
        Fact("AgeClass", "adult", "minAge", 18, ValueType.UINT32),
        Fact("Person", "jane", "age", 30, ValueType.UINT32),
        Fact("Person", "tom", "age", 9, ValueType.UINT32),
    ])

    stats = engine.infer()
    print(f"inferred {stats.facts_inferred} facts in "
          f"{stats.iterations} fixpoint iterations "
          f"({stats.seconds*1e3:.1f} ms)")

    print("\nUSD profits:")
    for row in engine.query([cond("DailySales", "?s", "profitUSD", "?v",
                                  ValueType.DOUBLE)]):
        print(" ", row)

    print("\nage classes:")
    for row in engine.query([cond("Person", "?x", "inClass", "?c")]):
        print(" ", row)


def _mul(p, f):
    from repro.core.facts import decode_lane_array, encode_lane_array, \
        ValueType as VT
    import numpy as np
    return encode_lane_array(
        decode_lane_array(np.asarray(p), VT.DOUBLE)
        * decode_lane_array(np.asarray(f), VT.DOUBLE), VT.DOUBLE)


if __name__ == "__main__":
    main()
