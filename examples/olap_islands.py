"""Island processing on a TPC-style OLAP schema (paper Fig. 7).

Shows the planner grouping a multi-star query into islands, ordering
them by estimated cost, and the effect on intermediate join sizes.

    PYTHONPATH=src python examples/olap_islands.py
"""

import time

import numpy as np

from repro.core import EngineConfig, Fact, HiperfactEngine, ValueType
from repro.core.conditions import Rule, cond
from repro.core.islands import build_islands, evaluate_rule, order_islands


def build_shop_kg(n_customers=1000, n_sales=4000, n_returns=400, seed=0):
    rng = np.random.RandomState(seed)
    facts = []
    for c in range(n_customers):
        facts.append(Fact("Customer", f"c{c}", "segment",
                          f"seg{rng.randint(5)}"))
    for s in range(n_sales):
        cid = f"c{rng.randint(n_customers)}"
        facts.append(Fact("StoreSale", f"s{s}", "customer", cid))
        facts.append(Fact("StoreSale", f"s{s}", "item",
                          f"i{rng.randint(200)}"))
        facts.append(Fact("StoreSale", f"s{s}", "amount",
                          int(rng.randint(1, 500)), ValueType.INT64))
    for r in range(n_returns):
        facts.append(Fact("StoreReturn", f"r{r}", "customer",
                          f"c{rng.randint(n_customers)}"))
        facts.append(Fact("StoreReturn", f"r{r}", "item",
                          f"i{rng.randint(200)}"))
    return facts


def main() -> None:
    engine = HiperfactEngine(EngineConfig.query1())
    engine.insert_facts(build_shop_kg())

    # "customers in segment seg0 who returned an item they bought"
    query = (
        cond("Customer", "?c", "segment", "seg0"),
        cond("StoreSale", "?s", "customer", "?c"),
        cond("StoreSale", "?s", "item", "?i"),
        cond("StoreReturn", "?r", "customer", "?c"),
        cond("StoreReturn", "?r", "item", "?i"),
    )
    rule = Rule("returned-purchases", query)

    islands = build_islands(engine.store, rule)
    print("islands detected (paper Fig. 7 style):")
    for isl in order_islands(islands):
        conds = ", ".join(f"{s.cond.fact_type}(card={s.card:.0f})"
                          for s in isl.stats)
        print(f"  island ?{isl.key:3s} cost={isl.total_cost:9.0f}  [{conds}]")

    t0 = time.perf_counter()
    result = evaluate_rule(engine.store, rule, distinct=True)
    dt = time.perf_counter() - t0
    print(f"\nquery answered: {result.n} rows in {dt*1e3:.1f} ms")
    for i in range(min(5, result.n)):
        row = {k: int(result.col(k)[i]) for k in result.names()}
        print("  ", {k: engine.store.strings.lookup_id(v)
                     for k, v in row.items()})


if __name__ == "__main__":
    main()
