"""Streaming appends: load -> infer -> append -> re-infer, with stats.

    PYTHONPATH=src python examples/streaming_append.py [--backend B]
                                                       [--rounds N]

The serving-shaped workload the delta machinery targets: a knowledge
base is loaded and closed once, then small fact batches stream in and
`infer()` is called after each.  Three layers keep the per-round cost
proportional to the append (Δ), not the store (N) — each is printed per
round so the scaling is visible, not asserted:

* **semi-naive evaluation** (`eval_mode="auto"`): only rule passes whose
  append frontier is non-empty run, against O(Δ) tail scans
  (`delta_passes` vs `full_evals`, `rows_considered`);
* **delta-only uploads** (device backends): resident column buffers
  extend in place, so `h2d` bytes are delta buckets;
* **merge-maintained index mirrors** (device backends): the rank-1
  (sorted, perm) mirrors absorb each append by delta-run merge —
  `merged` bytes ∝ Δ — instead of full re-sorts (`sorted` bytes ∝ N).

Run with `--backend jax-interpret` to exercise the real device code path
on a CPU container (the CI smoke pass does); `numpy` shows the
host-side semi-naive stats only.
"""

from __future__ import annotations

import argparse

from repro.core import EngineConfig, Fact, HiperfactEngine, Rule
from repro.core.conditions import AddAction, cond, term


def make_rules() -> list[Rule]:
    return [
        Rule("subclass-trans",
             (cond("Schema", "?a", "subClassOf", "?b"),
              cond("Schema", "?b", "subClassOf", "?c")),
             (AddAction("Schema", term("?a"), "subClassOf", term("?c")),)),
        Rule("type-inherit",
             (cond("Data", "?x", "type", "?t"),
              cond("Schema", "?t", "subClassOf", "?u")),
             (AddAction("Data", term("?x"), "type", term("?u")),)),
        Rule("knows-symmetric",
             (cond("Data", "?x", "knows", "?y"),),
             (AddAction("Data", term("?y"), "knows", term("?x")),)),
    ]


def base_facts(n_classes: int = 12, n_entities: int = 400) -> list[Fact]:
    facts = [Fact("Schema", f"C{i}", "subClassOf", f"C{i + 1}")
             for i in range(n_classes - 1)]
    for e in range(n_entities):
        facts.append(Fact("Data", f"e{e}", "type", f"C{e % n_classes}"))
        if e:
            facts.append(Fact("Data", f"e{e}", "knows", f"e{e - 1}"))
    return facts


def append_batch(round_idx: int, batch: int = 25) -> list[Fact]:
    off = 10_000 + round_idx * batch
    return [Fact("Data", f"e{off + i}", "type", f"C{i % 3}")
            for i in range(batch)] + [
        Fact("Data", f"e{off + i}", "knows", f"e{off + i - 1}")
        for i in range(1, batch)]


def counters(ops):
    """(transfers, sort_work) snapshots, or (None, None) on host."""
    tc = getattr(ops, "transfers", None)
    sw = getattr(ops, "sort_work", None)
    return (tc.snapshot() if tc else None, sw.snapshot() if sw else None)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="numpy",
                    choices=["numpy", "jax", "jax-pallas", "jax-interpret"])
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--entities", type=int, default=400,
                    help="base dataset size (CI smoke uses a small one)")
    args = ap.parse_args()

    import dataclasses
    # AI = the sorted-mirror index, rebuilt per append — the config whose
    # appends exercise merge maintenance (LPIM would batch them into an
    # unsorted tail and compact later)
    cfg = dataclasses.replace(EngineConfig.infer1(args.backend),
                              index_backend="AI")
    engine = HiperfactEngine(cfg)
    engine.add_rules(make_rules())

    # -- load + initial closure -------------------------------------------
    engine.insert_facts(base_facts(n_entities=args.entities))
    stats = engine.infer()
    print(f"load: {engine.store.num_facts()} facts, initial infer "
          f"{stats.seconds:.3f}s -> +{stats.facts_inferred} inferred "
          f"in {stats.iterations} rounds")

    # -- streaming appends ------------------------------------------------
    for r in range(args.rounds):
        tc0, sw0 = counters(engine.ops)
        engine.insert_facts(append_batch(r))
        stats = engine.infer()
        line = (f"round {r}: infer {stats.seconds:.3f}s "
                f"+{stats.facts_inferred} facts  "
                f"delta_passes={stats.delta_passes} "
                f"full_evals={stats.full_evals} "
                f"rows_considered={stats.rows_considered}")
        if tc0 is not None:
            d = engine.ops.transfers.delta(tc0)
            ds = engine.ops.sort_work.delta(sw0)
            line += (f"  h2d={d.h2d_bytes}B sorted={ds.sorted_bytes}B "
                     f"merged={ds.merged_bytes}B")
        print(line)

    # -- the re-infer at fixpoint is (nearly) free ------------------------
    tc0, _ = counters(engine.ops)
    stats = engine.infer()
    tail = ""
    if tc0 is not None:
        d = engine.ops.transfers.delta(tc0)
        tail = f" ({d.h2d_calls} h2d, {d.d2h_calls} d2h transfers)"
    print(f"fixpoint re-infer: {stats.seconds:.3f}s, "
          f"+{stats.facts_inferred} facts{tail}")

    n = engine.store.num_facts()
    got = engine.query([cond("Data", "?x", "type", "C11")])
    print(f"done: {n} facts total; {len(got)} entities reach type C11")
    assert stats.facts_inferred == 0  # fixpoint reached


if __name__ == "__main__":
    main()
