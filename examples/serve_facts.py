"""Concurrent fact serving in ~60 lines: a writer thread streams edges
into a transitive-closure store while reader threads serve snapshot-
isolated point queries — every result pinned to one MVCC token, repeat
queries folded from signed delta windows, point probes coalesced into
batched device calls.

    PYTHONPATH=src python examples/serve_facts.py --backend numpy
"""

from __future__ import annotations

import argparse
import dataclasses
import threading


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="numpy")
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--chains", type=int, default=4)
    ap.add_argument("--hops", type=int, default=6)
    ap.add_argument("--appends", type=int, default=6)
    ap.add_argument("--reads", type=int, default=12)
    args = ap.parse_args()

    from repro.core import EngineConfig, Fact, HiperfactEngine, Rule
    from repro.core.conditions import AddAction, cond, term
    from repro.serve import FactServer

    cfg = dataclasses.replace(EngineConfig.infer1(args.backend),
                              eval_mode="delta", shards=args.shards)
    e = HiperfactEngine(cfg)
    e.add_rules([
        Rule("base", (cond("edge", "?x", "to", "?y"),),
             (AddAction("path", term("?x"), "to", term("?y")),)),
        Rule("rec", (cond("edge", "?x", "to", "?y"),
                     cond("path", "?y", "to", "?z")),
             (AddAction("path", term("?x"), "to", term("?z")),)),
    ])
    e.insert_facts([Fact("edge", f"c{j}_n{i}", "to", f"c{j}_n{i + 1}")
                    for j in range(args.chains) for i in range(args.hops)])
    e.infer()

    with FactServer(e) as srv:
        q = [cond("path", "c0_n0", "to", "?z")]

        def writer() -> None:
            for i in range(args.appends):
                srv.append([Fact("edge", f"c0_n{args.hops + i}", "to",
                                 f"c0_n{args.hops + i + 1}")])

        def reader(r: int) -> None:
            for i in range(args.reads):
                res = srv.serve(q, tenant=f"tenant{r}")
                print(f"  tenant{r} read {i}: {len(res.rows)} rows "
                      f"via {res.mode}")

        threads = [threading.Thread(target=writer)] + [
            threading.Thread(target=reader, args=(r,)) for r in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        st = srv.stats()
        print(f"served modes: {st['served']}")
        print(f"requery: {st['requery']}")
        final = srv.serve(q)
        print(f"final frontier: {len(final.rows)} hops reachable "
              f"from c0_n0 at token {final.token[:1]}...")


if __name__ == "__main__":
    main()
