"""The paper's engine at pod scale: distributed transitive closure.

Runs the semi-naive closure (core/distributed.py) over an 8-host-device
mesh — the same shard_map program the multi-pod dry-run lowers on 512
chips.  MUST set XLA_FLAGS before any jax import, which this script does
itself::

    PYTHONPATH=src python examples/distributed_closure.py
"""

import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

# ruff: noqa: E402
import time

import numpy as np


def main() -> None:
    import jax
    from jax.sharding import Mesh
    from repro.core.distributed import ClosureConfig, DistributedClosure

    devices = jax.devices()
    if len(devices) < 8:
        # a silent [:N] slice would build a degenerate mesh and skew
        # every number printed below — fail with the fix instead
        raise SystemExit(
            f"need 8 devices for the 2x4 mesh, found {len(devices)}.\n"
            f"XLA_FLAGS was already set in the environment, so this "
            f"script did not force host devices; either unset it or "
            f"add: --xla_force_host_platform_device_count=8")
    mesh = Mesh(np.array(devices[:8]).reshape(2, 4), ("data", "model"))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    # random DAG-ish edge set
    rng = np.random.RandomState(7)
    n_nodes, n_edges = 250, 700
    src = rng.randint(0, n_nodes, n_edges)
    dst = np.minimum(src + rng.randint(1, 12, n_edges), n_nodes - 1)

    dc = DistributedClosure(mesh, ClosureConfig(
        edge_cap=1 << 15, delta_cap=1 << 13, slot_cap=1 << 11,
        join_cap=1 << 15))
    t0 = time.perf_counter()
    pairs, iters = dc.run(src, dst, max_iters=64)
    dt = time.perf_counter() - t0
    print(f"closure: {len(pairs)} pairs from {n_edges} edges "
          f"in {iters} semi-naive iterations ({dt:.2f}s)")

    # verify against a host oracle (semi-naive in numpy)
    want = set(zip(src.tolist(), dst.tolist()))
    frontier = set(want)
    by_src: dict[int, list[int]] = {}
    for a, b in zip(src.tolist(), dst.tolist()):
        by_src.setdefault(a, []).append(b)
    while frontier:
        new = {(a, c) for (a, b) in frontier for c in by_src.get(b, ())}
        frontier = new - want
        want |= frontier
    want_packed = sorted((a << 32) | b for a, b in want)
    ok = sorted(int(p) for p in pairs) == want_packed
    print(f"host-oracle check: {'OK' if ok else 'MISMATCH'} "
          f"({len(want)} pairs)")
    assert ok


if __name__ == "__main__":
    main()
