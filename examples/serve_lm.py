"""Batched serving example: prefill + continuous-batching greedy decode.

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import numpy as np


def main() -> None:
    import jax
    from repro.configs import get_config
    from repro.models import build_model, init_params
    from repro.serve import BatchScheduler, Request, ServeEngine

    cfg = get_config("mamba2-1.3b", smoke=True)  # O(1)-state decode
    model = build_model(cfg)
    params = init_params(model.spec(), jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_len=96, batch=4)
    sched = BatchScheduler(engine)

    rng = np.random.RandomState(0)
    for i in range(10):
        sched.submit(Request(
            uid=i, prompt=rng.randint(0, cfg.vocab, 12).astype(np.int32),
            max_new=24))
    t0 = time.perf_counter()
    done = sched.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests / {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, arch={cfg.name})")
    for r in done[:3]:
        print(f"  req {r.uid}: {r.out}")


if __name__ == "__main__":
    main()
