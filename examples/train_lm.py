"""End-to-end training driver.

Default (laptop-scale, ~2 min): a tiny qwen2-family model on the
Hiperfact-derived fact corpus.  ``--preset 100m`` trains a ~100M-param
model for a few hundred steps (the brief's end-to-end driver; several
hours on this CPU container, the intended target is a TPU slice):

    PYTHONPATH=src python examples/train_lm.py                 # tiny
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

Multi-device (8 host devices, FSDP+TP):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python examples/train_lm.py --mesh 2x4
"""

from __future__ import annotations

import argparse
import dataclasses
import logging


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--data", default="facts", choices=["facts", "synthetic"])
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    import jax
    from repro.configs import get_config
    from repro.data import DataConfig, ShardedLoader, SyntheticLM
    from repro.train import OptimizerConfig, Trainer, TrainerConfig

    base = get_config("qwen2-7b", smoke=True)
    if args.preset == "100m":
        cfg = dataclasses.replace(
            base, name="qwen2-100m", n_layers=16, d_model=512, n_heads=8,
            n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32_000,
            q_chunk=256, kv_chunk=256, logit_chunk=128)  # ~96M params
        steps = args.steps or 300
        seq, batch = 512, args.batch or 8
        lr = 6e-4
    else:
        cfg = base
        steps = args.steps or 60
        seq, batch = 128, args.batch or 8
        lr = 1e-3
    print(f"model: {cfg.name}  params~{cfg.param_count()/1e6:.1f}M")

    mesh = None
    if args.mesh:
        a, b = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((a, b), ("data", "model"))

    if args.data == "facts":
        from repro.data.factsource import FactCorpusSource
        src = FactCorpusSource(cfg.vocab, seq, batch)
        print(f"fact corpus: {src.engine.store.num_facts()} facts "
              f"({src.engine.last_infer.facts_inferred} inferred)")
    else:
        src = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                     global_batch=batch))
    trainer = Trainer(
        cfg, ShardedLoader(src),
        OptimizerConfig(lr=lr, warmup_steps=max(5, steps // 20),
                        total_steps=steps),
        TrainerConfig(steps=steps, log_every=max(1, steps // 20),
                      ckpt_dir=args.ckpt_dir,
                      ckpt_every=max(10, steps // 4)),
        mesh=mesh, global_batch=batch)
    _, losses = trainer.run()
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {steps} steps")


if __name__ == "__main__":
    main()
