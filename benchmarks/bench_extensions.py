"""Paper §5 future-work extensions: rank-N query cache + CR compression."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.datasets import mondial_like, mondial_queries
from repro.core import EngineConfig, HiperfactEngine
from repro.core.compress import CompressedBindings


def bench_query_cache(repeats: int = 20):
    facts = mondial_like(20, 80)
    qs = mondial_queries()
    rows = []
    import dataclasses
    for label, cached in (("no-cache", False), ("rankN-cache", True)):
        e = HiperfactEngine(dataclasses.replace(EngineConfig.query1(),
                                                query_cache=cached))
        e.insert_facts(facts)
        for q in qs:
            e.query(q, decode=False)  # prime
        t0 = time.perf_counter()
        for _ in range(repeats):
            for q in qs:
                e.query(q, decode=False)
        dt = (time.perf_counter() - t0) / repeats
        stats = e.query_cache.stats() if e.query_cache else {}
        rows.append((label, dt, stats.get("hit_rate", 0.0)))
    return rows


def bench_compression():
    """Compression ratio + codec pick on realistic join-output columns."""
    rng = np.random.RandomState(0)
    cases = {
        "join-key-runs": np.repeat(np.arange(500, dtype=np.int64), 40),
        "sorted-row-ids": np.cumsum(rng.randint(1, 5, 20000)).astype(np.int64),
        "random-values": rng.randint(0, 2**48, 20000).astype(np.int64),
    }
    rows = []
    for name, col in cases.items():
        t0 = time.perf_counter()
        cb = CompressedBindings({"c": col})
        enc_s = time.perf_counter() - t0
        ratio = col.nbytes / max(1, cb.nbytes())
        rows.append((name, cb.codecs()["c"], ratio, enc_s))
    return rows


def main():
    print("query-cache: config,seconds,hit_rate")
    for label, dt, hr in bench_query_cache():
        print(f"{label},{dt:.5f},{hr:.2f}")
    print("compression: column,codec,ratio,encode_s")
    for name, codec, ratio, enc_s in bench_compression():
        print(f"{name},{codec},{ratio:.1f}x,{enc_s:.4f}")


if __name__ == "__main__":
    main()
