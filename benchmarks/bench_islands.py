"""Island-processing internals: AR vs DR, sort keys vs fixed, island order
(paper §2.3 internal evaluation + Fig. 6 example)."""

from __future__ import annotations

import time

from benchmarks.datasets import mondial_like, mondial_queries
from repro.core import EngineConfig, HiperfactEngine
from repro.core.conditions import Rule
from repro.core.islands import build_islands, evaluate_rule, order_islands


def bench_rnl_modes(n_countries=20, cities_per=80):
    facts = mondial_like(n_countries, cities_per)
    e = HiperfactEngine(EngineConfig.query1())
    e.insert_facts(facts)
    q = mondial_queries()[0]
    rule = Rule("q", tuple(q))
    rows = []
    for rnl in ("AR", "DR"):
        for sort_mode in ("sortkeys", "fixed"):
            # warm
            evaluate_rule(e.store, rule, rnl_mode=rnl, sort_mode=sort_mode)
            t0 = time.perf_counter()
            for _ in range(5):
                b = evaluate_rule(e.store, rule, rnl_mode=rnl,
                                  sort_mode=sort_mode)
            dt = (time.perf_counter() - t0) / 5
            rows.append((f"RNL={rnl}/sort={sort_mode}", dt, b.n))
    return rows


def bench_island_order(n_countries=20, cities_per=80):
    """Cheapest-island-first vs worst-first: intermediate result sizes."""
    facts = mondial_like(n_countries, cities_per)
    e = HiperfactEngine(EngineConfig.query1())
    e.insert_facts(facts)
    q = mondial_queries()[0]
    rule = Rule("q", tuple(q))
    islands = build_islands(e.store, rule)
    ordered = order_islands(islands)
    t0 = time.perf_counter()
    for _ in range(5):
        evaluate_rule(e.store, rule, islands=islands)
    good = (time.perf_counter() - t0) / 5
    # adversarial: reverse island cost order by inflating the cheap one
    rev = list(reversed(ordered))
    for isl in rev:
        isl.total_cost = -isl.total_cost
    t0 = time.perf_counter()
    for _ in range(5):
        evaluate_rule(e.store, rule, islands=rev)
    bad = (time.perf_counter() - t0) / 5
    return [("island_order=planner", good), ("island_order=reversed", bad)]


def main():
    print("config,seconds,rows")
    for label, dt, n in bench_rnl_modes():
        print(f"{label},{dt:.5f},{n}")
    print("config,seconds")
    for label, dt in bench_island_order():
        print(f"{label},{dt:.5f}")


if __name__ == "__main__":
    main()
