"""Render EXPERIMENTS.md §Dry-run tables from the artifacts.

    PYTHONPATH=src python -m benchmarks.dryrun_tables
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs import ARCH_NAMES

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_dir(d):
    out = {}
    for p in glob.glob(os.path.join(d, "*.json")):
        r = json.load(open(p))
        out[(r["arch"], r["shape"])] = r
    return out


def peak_table(d="out/dryrun/single"):
    recs = load_dir(d)
    print(f"peak GiB/device ({d}):")
    print("| arch | " + " | ".join(SHAPE_ORDER) + " |")
    print("|---|" + "---|" * len(SHAPE_ORDER))
    for a in ARCH_NAMES:
        row = [a]
        for s in SHAPE_ORDER:
            r = recs.get((a, s))
            if r is None:
                row.append("skip")
            else:
                row.append(f"{r['memory']['peak_bytes_per_device']/2**30:.1f}")
        print("| " + " | ".join(row) + " |")
    r = recs.get(("hiperfact-closure", "closure_64k"))
    if r:
        print(f"| hiperfact-closure | "
              f"{r['memory']['peak_bytes_per_device']/2**30:.2f} (infer) | | | |")


def compile_stats(d="out/dryrun/single"):
    recs = load_dir(d)
    total = sum(r["compile_s"] for r in recs.values())
    worst = max(recs.values(), key=lambda r: r["compile_s"])
    print(f"{d}: {len(recs)} cells, total compile {total:.0f}s, "
          f"worst {worst['arch']}__{worst['shape']} {worst['compile_s']:.0f}s")


if __name__ == "__main__":
    for d in ("out/dryrun/single", "out/dryrun/multi"):
        if os.path.isdir(d):
            peak_table(d)
            compile_stats(d)
            print()
