"""Query benchmarks — the paper's Table 4 analog (OpenRuleBench style).

Full internal config matrix (index backend x join x RNL x layout) on
Mondial/DBLP-like star-join workloads; plus the Rete baseline.
"""

from __future__ import annotations

import itertools
import time

from benchmarks.datasets import (dblp_like, dblp_queries, mondial_like,
                                 mondial_queries)
from repro.core import EngineConfig, HiperfactEngine


def config_matrix():
    # the exact configurations of the paper's Table 4
    combos = [
        ("LPIM", "HJ", "AR", "CR"), ("LPIM", "HJ", "DR", "CR"),
        ("LPIM", "HJ", "AR", "RR"), ("LPIM", "MJ", "AR", "CR"),
        ("LPID", "HJ", "AR", "CR"), ("AI", "HJ", "AR", "CR"),
        ("AI", "MJ", "AR", "CR"), ("AI", "HJ", "AR", "RR"),
        ("AI", "HJ", "DR", "CR"), ("AI", "MJ", "DR", "CR"),
    ]
    for idx, join, rnl, layout in combos:
        yield (f"{idx}+{join}/{rnl}/{layout}",
               EngineConfig(index_backend=idx, join=join, rnl=rnl,
                            layout=layout))


def bench_one(cfg: EngineConfig, facts, queries, repeats: int = 3):
    e = HiperfactEngine(cfg)
    t0 = time.perf_counter()
    e.insert_facts(facts)
    load_s = time.perf_counter() - t0
    # prime (paper: first run primes caches), then average 3
    for q in queries:
        e.query(q, decode=False)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        for q in queries:
            e.query(q, decode=False)
        times.append(time.perf_counter() - t0)
    return {"load_s": load_s, "query_s": sum(times) / len(times)}


def bench(mondial_kw=None, dblp_kw=None, backend: str = "numpy"):
    import dataclasses
    datasets = {
        "mondial_like": (mondial_like(**(mondial_kw or {})),
                         mondial_queries()),
        "dblp_like": (dblp_like(**(dblp_kw or {})), dblp_queries()),
    }
    rows = []
    for dname, (facts, queries) in datasets.items():
        for label, cfg in config_matrix():
            cfg = dataclasses.replace(cfg, backend=backend)
            rows.append((dname, label, bench_one(cfg, facts, queries)))
    return rows


def main():
    print("dataset,config,load_s,query_s")
    for dname, label, r in bench():
        print(f"{dname},{label},{r['load_s']:.4f},{r['query_s']:.6f}")


if __name__ == "__main__":
    main()
