"""Roofline report: reads the dry-run artifacts and prints the per-cell
three-term roofline table + MODEL_FLOPS/HLO_FLOPs utilization ratios.

    PYTHONPATH=src python -m benchmarks.roofline [--dir out/dryrun/single]

MODEL_FLOPS convention (per the brief): 6*N*D for dense (D = tokens
processed by the step), 6*N_active*D for MoE; decode steps process
global_batch tokens; prefill processes batch*seq.  The HLO FLOPs are
per-device x devices (from the structural analyzer, loop-corrected).
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import get_config
from repro.models import SHAPES

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def model_flops(rec: dict) -> float:
    """Analytic useful FLOPs for the whole step (all devices)."""
    arch = rec["arch"]
    if arch == "hiperfact-closure":
        return 0.0
    cfg = get_config(arch)
    shape = SHAPES[rec["shape"]]
    n_active = cfg.active_param_count()
    if rec["kind"] == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if rec["kind"] == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def load(dirpath: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def report(recs: list[dict]) -> list[dict]:
    rows = []
    for r in recs:
        n_dev = r["mesh"]["devices"]
        hf = r["hlo"]["flops_per_device"]
        terms = r["roofline"]
        dom = max(terms, key=terms.get)
        total = max(terms.values())
        mf = model_flops(r)
        util = mf / (hf * n_dev) if hf else 0.0
        # roofline fraction: useful-FLOPs time / dominant-term time
        ideal_s = (mf / n_dev) / PEAK_FLOPS if mf else 0.0
        frac = ideal_s / total if total else 0.0
        rows.append({
            "cell": f"{r['arch']}__{r['shape']}",
            "compute_s": terms["compute_s"],
            "memory_s": terms["memory_s"],
            "collective_s": terms["collective_s"],
            "bottleneck": dom,
            "model_flops": mf,
            "hlo_flops_total": hf * n_dev,
            "useful_ratio": util,
            "roofline_frac": frac,
            "peak_gib": r.get("memory", {}).get(
                "peak_bytes_per_device", 0) / 2**30,
        })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="out/dryrun/single")
    args = ap.parse_args()
    rows = report(load(args.dir))
    hdr = (f"{'cell':42s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'collect_s':>10s} {'bound':>12s} {'useful%':>8s} "
           f"{'roofl%':>7s} {'GiB/dev':>8s}")
    print(hdr)
    for r in rows:
        print(f"{r['cell']:42s} {r['compute_s']:10.4f} "
              f"{r['memory_s']:10.4f} {r['collective_s']:10.4f} "
              f"{r['bottleneck'][:12]:>12s} {100*r['useful_ratio']:8.1f} "
              f"{100*r['roofline_frac']:7.2f} {r['peak_gib']:8.2f}")


if __name__ == "__main__":
    main()
