"""Inference benchmarks — the paper's Table 2 analog.

engines x datasets -> (load_s, infer_s, query_s, facts_inferred).
Engines: Hiperfact presets (infer1/query1), the degraded config the
paper uses as its internal worst case (HI+HJ/DR/RR+SF/SW/HU), infer1+HU,
and the classic Rete baseline.
"""

from __future__ import annotations

import time

from benchmarks.datasets import (LUBM_QUERIES, WORDNET_QUERIES, lubm_like,
                                 wordnet_like)
from repro.core import EngineConfig, HiperfactEngine
from repro.core.rete_baseline import ReteEngine
from repro.core.rulesets import rdfs_plus_rules

ENGINE_CONFIGS = {
    "hiperfact_infer1": EngineConfig.infer1(),
    "hiperfact_query1": EngineConfig.query1(),
    "hiperfact_infer1+HU": EngineConfig(
        index_backend="LPIM", join="HJ", rnl="AR", layout="CR", unique="HU"),
    "hiperfact_worst(HI+HJ/DR/RR+SF/SW/HU)": EngineConfig(
        index_backend="HI", join="HJ", rnl="DR", layout="RR",
        tree_exec="SF", index_write="SW", unique="HU"),
}


def run_hiperfact(cfg: EngineConfig, facts, queries) -> dict:
    e = HiperfactEngine(cfg)
    tc = getattr(e.ops, "transfers", None)  # JaxOps: measure residency
    snap = tc.snapshot() if tc is not None else None
    cache_snap = e.ops.cache.stats() if tc is not None else None
    e.add_rules(rdfs_plus_rules())
    t0 = time.perf_counter()
    e.insert_facts(facts)
    load_s = time.perf_counter() - t0
    stats = e.infer()
    t0 = time.perf_counter()
    n_rows = sum(len(e.query(q, decode=False).names()) or
                 e.query(q, decode=False).n for q in queries)
    query_s = time.perf_counter() - t0
    # same queries again at the (now fixed) table versions: on the device
    # pipeline this is the memoized join core — the serving-shaped
    # workload the paper's query nodes model
    t0 = time.perf_counter()
    for q in queries:
        e.query(q, decode=False)
    requery_s = time.perf_counter() - t0
    out = {"load_s": load_s, "infer_s": stats.seconds,
           "query_s": query_s, "requery_s": requery_s,
           "inferred": stats.facts_inferred, "rows": n_rows}
    if tc is not None:
        d = tc.delta(snap)
        out["transfers"] = {"h2d_calls": d.h2d_calls,
                            "h2d_bytes": d.h2d_bytes,
                            "d2h_calls": d.d2h_calls,
                            "d2h_bytes": d.d2h_bytes}
        # the backend instance is process-wide: report this run's delta,
        # not cumulative totals (entries/bytes are point-in-time gauges);
        # evictions vs spilled distinguishes capacity thrash from
        # cooperative refresh() spills
        cur = e.ops.cache.stats()
        out["cache"] = {k: (cur[k] - cache_snap[k]
                            if k in ("hits", "misses", "stale",
                                     "evictions", "spilled", "refreshes")
                            else cur[k]) for k in cur}
        e.ops.cache.refresh()  # engine done: release its idle residency
    return out


def fmt_transfers(t: dict) -> str:
    return (f"h2d={t['h2d_calls']}x/{t['h2d_bytes']}B "
            f"d2h={t['d2h_calls']}x/{t['d2h_bytes']}B")


def run_rete(facts, queries) -> dict:
    r = ReteEngine()
    for rr in rdfs_plus_rules():
        r.add_rule(rr)
    t0 = time.perf_counter()
    r.insert(facts)
    load_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    inferred = r.infer()
    infer_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    n_rows = sum(len(r.query(q)) for q in queries)
    query_s = time.perf_counter() - t0
    return {"load_s": load_s, "infer_s": infer_s, "query_s": query_s,
            "inferred": inferred, "rows": n_rows}


def bench(scale: int = 1, wordnet_n: int = 1500, include_rete: bool = True,
          runs: int = 1, backend: str = "numpy", smoke: bool = False):
    import dataclasses
    if smoke:  # CI-sized: one tiny dataset, the two presets, no Rete
        datasets = {"wordnet_like(150)": (wordnet_like(150),
                                          WORDNET_QUERIES)}
        configs = {k: ENGINE_CONFIGS[k]
                   for k in ("hiperfact_infer1", "hiperfact_query1")}
        include_rete = False
    else:
        datasets = {
            f"lubm_like(x{scale})": (lubm_like(scale), LUBM_QUERIES),
            f"wordnet_like({wordnet_n})": (wordnet_like(wordnet_n),
                                           WORDNET_QUERIES),
        }
        configs = ENGINE_CONFIGS
    configs = dict(configs)
    if backend != "numpy":
        # the acceptance comparison: fused handle pipeline (default on
        # device backends) vs the PR 2 per-primitive path
        for k in ("hiperfact_infer1", "hiperfact_query1"):
            configs[f"{k}[per-primitive]"] = dataclasses.replace(
                configs[k], device_pipeline="off")
    rows = []
    for dname, (facts, queries) in datasets.items():
        for ename, base_cfg in configs.items():
            cfg = dataclasses.replace(base_cfg, backend=backend)
            best = None
            for _ in range(runs):
                r = run_hiperfact(cfg, facts, queries)
                best = r if best is None or r["infer_s"] < best["infer_s"] \
                    else best
            rows.append((dname, ename, best))
        if include_rete:
            # Rete is O(facts^2)-ish here; cap to keep the bench bounded
            if len(facts) <= 30_000:
                rows.append((dname, "rete_baseline",
                             run_rete(facts, queries)))
    return rows


def main(scale: int = 1, backend: str = "numpy"):
    print("dataset,engine,load_s,infer_s,query_s,facts_inferred")
    for dname, ename, r in bench(scale, backend=backend):
        print(f"{dname},{ename},{r['load_s']:.4f},{r['infer_s']:.4f},"
              f"{r['query_s']:.4f},{r['inferred']}")
        if "transfers" in r:
            print(f"#   {ename}: {fmt_transfers(r['transfers'])} "
                  f"cache={r['cache']}")


if __name__ == "__main__":
    import sys
    main(scale=int(sys.argv[1]) if len(sys.argv) > 1 else 1,
         backend=sys.argv[2] if len(sys.argv) > 2 else "numpy")
