"""Inference benchmarks — the paper's Table 2 analog.

engines x datasets -> (load_s, infer_s, query_s, facts_inferred).
Engines: Hiperfact presets (infer1/query1), the degraded config the
paper uses as its internal worst case (HI+HJ/DR/RR+SF/SW/HU), infer1+HU,
and the classic Rete baseline.
"""

from __future__ import annotations

import time

from benchmarks.datasets import (LUBM_QUERIES, WORDNET_QUERIES, lubm_like,
                                 wordnet_like)
from repro.core import EngineConfig, HiperfactEngine
from repro.core.rete_baseline import ReteEngine
from repro.core.rulesets import rdfs_plus_rules

ENGINE_CONFIGS = {
    "hiperfact_infer1": EngineConfig.infer1(),
    "hiperfact_query1": EngineConfig.query1(),
    "hiperfact_infer1+HU": EngineConfig(
        index_backend="LPIM", join="HJ", rnl="AR", layout="CR", unique="HU"),
    "hiperfact_worst(HI+HJ/DR/RR+SF/SW/HU)": EngineConfig(
        index_backend="HI", join="HJ", rnl="DR", layout="RR",
        tree_exec="SF", index_write="SW", unique="HU"),
}


def run_hiperfact(cfg: EngineConfig, facts, queries) -> dict:
    e = HiperfactEngine(cfg)
    tc = getattr(e.ops, "transfers", None)  # JaxOps: measure residency
    snap = tc.snapshot() if tc is not None else None
    cache_snap = e.ops.cache.stats() if tc is not None else None
    sw = getattr(e.ops, "sort_work", None)  # mirror merge maintenance
    sw_snap = sw.snapshot() if sw is not None else None
    e.add_rules(rdfs_plus_rules())
    t0 = time.perf_counter()
    e.insert_facts(facts)
    load_s = time.perf_counter() - t0
    stats = e.infer()
    t0 = time.perf_counter()
    n_rows = sum(len(e.query(q, decode=False).names()) or
                 e.query(q, decode=False).n for q in queries)
    query_s = time.perf_counter() - t0
    # same queries again at the (now fixed) table versions: on the device
    # pipeline this is the memoized join core — the serving-shaped
    # workload the paper's query nodes model
    t0 = time.perf_counter()
    for q in queries:
        e.query(q, decode=False)
    requery_s = time.perf_counter() - t0
    out = {"load_s": load_s, "infer_s": stats.seconds,
           "query_s": query_s, "requery_s": requery_s,
           "inferred": stats.facts_inferred, "rows": n_rows}
    if tc is not None:
        d = tc.delta(snap)
        out["transfers"] = {"h2d_calls": d.h2d_calls,
                            "h2d_bytes": d.h2d_bytes,
                            "d2h_calls": d.d2h_calls,
                            "d2h_bytes": d.d2h_bytes}
        # the backend instance is process-wide: report this run's delta,
        # not cumulative totals (entries/bytes are point-in-time gauges);
        # evictions vs spilled distinguishes capacity thrash from
        # cooperative refresh() spills
        # per-run view: the backend instance (and its cache) is
        # process-wide, so report this run's delta, not the totals
        out["cache"] = e.ops.cache.delta_stats(cache_snap)
        if sw is not None:
            # device sort work split by path: full mirror sorts
            # (sorted_bytes) vs incremental delta-run merges
            # (merged_bytes) — see backend/README.md §Merge-maintained
            out["sort_work"] = sw.delta(sw_snap).as_dict()
        e.ops.cache.refresh()  # engine done: release its idle residency
    return out


def fmt_transfers(t: dict) -> str:
    return (f"h2d={t['h2d_calls']}x/{t['h2d_bytes']}B "
            f"d2h={t['d2h_calls']}x/{t['d2h_bytes']}B")


def fmt_sort_work(s: dict) -> str:
    return (f"sorted={s['full_sorts']}x/{s['sorted_bytes']}B "
            f"merged={s['delta_merges']}x/{s['merged_bytes']}B")


def run_rete(facts, queries) -> dict:
    r = ReteEngine()
    for rr in rdfs_plus_rules():
        r.add_rule(rr)
    t0 = time.perf_counter()
    r.insert(facts)
    load_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    inferred = r.infer()
    infer_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    n_rows = sum(len(r.query(q)) for q in queries)
    query_s = time.perf_counter() - t0
    return {"load_s": load_s, "infer_s": infer_s, "query_s": query_s,
            "inferred": inferred, "rows": n_rows}


def bench(scale: int = 1, wordnet_n: int = 1500, include_rete: bool = True,
          runs: int = 1, backend: str = "numpy", smoke: bool = False):
    import dataclasses
    if smoke:  # CI-sized: one tiny dataset, the two presets, no Rete
        datasets = {"wordnet_like(150)": (wordnet_like(150),
                                          WORDNET_QUERIES)}
        configs = {k: ENGINE_CONFIGS[k]
                   for k in ("hiperfact_infer1", "hiperfact_query1")}
        include_rete = False
    else:
        datasets = {
            f"lubm_like(x{scale})": (lubm_like(scale), LUBM_QUERIES),
            f"wordnet_like({wordnet_n})": (wordnet_like(wordnet_n),
                                           WORDNET_QUERIES),
        }
        configs = ENGINE_CONFIGS
    configs = dict(configs)
    if backend != "numpy":
        # the acceptance comparison: fused handle pipeline (default on
        # device backends) vs the PR 2 per-primitive path
        for k in ("hiperfact_infer1", "hiperfact_query1"):
            configs[f"{k}[per-primitive]"] = dataclasses.replace(
                configs[k], device_pipeline="off")
    rows = []
    for dname, (facts, queries) in datasets.items():
        for ename, base_cfg in configs.items():
            cfg = dataclasses.replace(base_cfg, backend=backend)
            best = None
            for _ in range(runs):
                r = run_hiperfact(cfg, facts, queries)
                best = r if best is None or r["infer_s"] < best["infer_s"] \
                    else best
            rows.append((dname, ename, best))
        if include_rete:
            # Rete is O(facts^2)-ish here; cap to keep the bench bounded
            if len(facts) <= 30_000:
                rows.append((dname, "rete_baseline",
                             run_rete(facts, queries)))
    return rows


def _fact_checksum(engine) -> tuple[int, int]:
    """Order-insensitive digest of every alive fact (type, id, attr,
    val): the delta-vs-full parity check must be bit-exact on the fact
    *set*, not on insertion order."""
    import zlib
    n = 0
    acc = 0
    for ftype, t in sorted(engine.store.tables.items()):
        alive = t.alive
        packed = (t.ids.astype("i8") << 40) ^ (t.attrs.astype("i8") << 20) \
            ^ t.vals.astype("i8")
        rows = sorted(int(x) for x in packed[alive])
        acc = zlib.crc32(repr((ftype, rows)).encode(), acc)
        n += len(rows)
    return n, acc


def bench_streaming(scale: int = 8, backend: str = "numpy",
                    eval_modes=("full", "delta"), n_rounds: int = 4,
                    batch: int = 80, runs: int = 2):
    """Streaming-append scenario: load -> infer -> append small batches
    -> re-infer, per eval_mode.  Reports per-round wall time, transfer
    bytes (device backends), the semi-naive stats, and the index
    sort-work split; the fact-set checksum asserts delta ≡ full.  Each
    mode's whole scenario runs ``runs`` times, keeping the best re-infer
    total (scheduler noise on millisecond rounds would otherwise
    dominate).

    The engine runs the AI (sorted-mirror) index — the paper's
    load-time winner / append-time loser — precisely because its
    eager per-append rebuild is the case merge maintenance targets:
    at steady state the per-round ``merged_bytes`` is the delta
    bucket while ``sorted_bytes`` stays 0 (LPIM would instead defer
    appends into an unsorted tail and show nothing per round)."""
    facts = lubm_like(scale)
    held = n_rounds * batch
    base, stream = facts[:-held], facts[-held:]
    batches = [stream[i * batch:(i + 1) * batch] for i in range(n_rounds)]
    out = []
    for mode in eval_modes:
        best = None
        for _ in range(max(1, runs)):
            res = _stream_once(mode, backend, base, batches)
            if best is None or res["reinfer_total_s"] < best["reinfer_total_s"]:
                best = res
        out.append(best)
    return out


def _stream_once(mode, backend, base, batches):
    import dataclasses
    cfg = dataclasses.replace(EngineConfig.infer1(backend),
                              eval_mode=mode, index_backend="AI")
    e = HiperfactEngine(cfg)
    tc = getattr(e.ops, "transfers", None)
    cache = getattr(e.ops, "cache", None)
    cache_snap = cache.stats() if tc is not None else None
    sw = getattr(e.ops, "sort_work", None)
    e.add_rules(rdfs_plus_rules())
    e.insert_facts(base)
    t0 = time.perf_counter()
    s0 = e.infer()
    initial_s = time.perf_counter() - t0
    rounds = []
    for b in batches:
        sw_snap = sw.snapshot() if sw is not None else None
        t0 = time.perf_counter()
        e.insert_facts(b)
        append_s = time.perf_counter() - t0
        snap = tc.snapshot() if tc is not None else None
        t0 = time.perf_counter()
        st = e.infer()
        infer_s = time.perf_counter() - t0
        row = {"append_s": append_s, "infer_s": infer_s,
               "inferred": st.facts_inferred,
               "rows_considered": st.rows_considered,
               "rows_emitted": st.rows_emitted,
               "delta_passes": st.delta_passes,
               "full_evals": st.full_evals}
        if tc is not None:
            d = tc.delta(snap)
            row["h2d_bytes"] = d.h2d_bytes
            row["d2h_bytes"] = d.d2h_bytes
        if sw is not None:
            # per-round device sort work (append + re-infer): at steady
            # state merged_bytes ∝ Δ while a full re-sort would pay the
            # whole column per touched mirror — the acceptance signal
            # for incremental index maintenance
            ds = sw.delta(sw_snap)
            row["sorted_bytes"] = ds.sorted_bytes
            row["merged_bytes"] = ds.merged_bytes
            row["delta_merges"] = ds.delta_merges
        rounds.append(row)
    n_facts, checksum = _fact_checksum(e)
    res = {"mode": mode, "facts_loaded": len(base),
           "initial_infer_s": initial_s,
           "initial_inferred": s0.facts_inferred,
           "rounds": rounds,
           "reinfer_total_s": sum(r["infer_s"] for r in rounds),
           "n_facts": n_facts, "checksum": checksum}
    if tc is not None:
        res["cache"] = cache.delta_stats(cache_snap)
        e.ops.cache.refresh()
    return res


def _expire_rules():
    from repro.core.conditions import AddAction, Rule, cond, term
    return [
        Rule("hot", (cond("Reading", "?s", "temp", "?t"),
                     cond("Threshold", "?t", "class", "hot")),
             (AddAction("Alert", term("?s"), "level", "hot"),)),
        Rule("zone-alert", (cond("Alert", "?s", "level", "hot"),
                            cond("Zone", "?s", "in", "?z")),
             (AddAction("ZoneAlert", term("?z"), "has", term("?s")),)),
        Rule("audit", (cond("ZoneAlert", "?z", "has", "?s"),),
             (AddAction("Audit", term("?z"), "saw", term("?s")),)),
    ]


def _expire_window(r: int, n_sensors: int):
    from repro.core.facts import Fact
    base = r * n_sensors
    readings = [Fact("Reading", f"s{base + i}", "temp", f"t{i % 7}")
                for i in range(n_sensors)]
    zones = [Fact("Zone", f"s{base + i}", "in", f"z{i % 4}")
             for i in range(n_sensors)]
    return readings, zones


def _expire_once(mode, backend, shards, n_rounds, n_sensors):
    import dataclasses

    from repro.core.facts import Fact
    from repro.core.sharded import decoded_fact_checksum

    cfg = dataclasses.replace(EngineConfig.infer1(backend),
                              eval_mode=mode, shards=shards)
    e = HiperfactEngine(cfg)
    for r in _expire_rules():
        e.add_rule(r)
    e.insert_facts([Fact("Threshold", f"t{k}", "class", "hot")
                    for k in (5, 6)])
    t0 = time.perf_counter()
    s0 = e.infer()
    initial_s = time.perf_counter() - t0
    rounds = []
    prev = None
    for r in range(n_rounds):
        readings, zones = _expire_window(r, n_sensors)
        e.insert_facts(readings + zones)
        t0 = time.perf_counter()
        sa = e.infer()
        append_s = time.perf_counter() - t0
        expire_s = 0.0
        sd = None
        if prev is not None:  # TTL: the previous window expires wholesale
            e.delete_facts(prev)
            t0 = time.perf_counter()
            sd = e.infer()
            expire_s = time.perf_counter() - t0
        prev = readings
        row = {"append_infer_s": append_s, "expire_infer_s": expire_s,
               "inferred": sa.facts_inferred,
               "retracted": (sd.facts_retracted + sd.facts_deleted
                             if sd else 0),
               "delta_passes": sa.delta_passes
               + (sd.delta_passes if sd else 0),
               "neg_passes": (sd.neg_passes if sd else 0),
               "full_evals": sa.full_evals + (sd.full_evals if sd else 0),
               "rows_considered": sa.rows_considered
               + (sd.rows_considered if sd else 0),
               "dred_scrubs": (sd.dred_scrubs if sd else 0)}
        rounds.append(row)
    n_facts = (e.num_facts() if shards > 1 else e.store.num_facts())
    return {"mode": mode, "shards": shards, "backend": backend,
            "facts_base": 2, "initial_infer_s": initial_s,
            "initial_inferred": s0.facts_inferred, "rounds": rounds,
            "reinfer_total_s": sum(r["append_infer_s"] + r["expire_infer_s"]
                                   for r in rounds),
            "n_facts": n_facts, "checksum": decoded_fact_checksum(e)}


def bench_streaming_expire(backend: str = "numpy", shards_list=(1,),
                           eval_modes=("full", "delta"), n_rounds: int = 4,
                           n_sensors: int = 120, runs: int = 2):
    """Append + bulk-expire rounds (IoT threshold rules): each round
    streams a window of sensor readings + zone memberships, infers the
    two-hop alert chain, then the previous window's readings expire
    wholesale (TTL) and the engine re-infers.

    The signed-frontier contract under test: ``eval_mode="delta"`` must
    (a) decode to the same fact set as ``"full"`` after every mixed
    append+expire round (``checksum`` parity, per shard count), and
    (b) run **zero** full re-evaluations in steady state — retractions
    ride O(Δ) negative inclusion–exclusion passes (``neg_passes``) over
    the delete log, with counting-based support retraction downstream,
    never a table rescan (``rows_considered`` stays ∝ window size)."""
    out = []
    for shards in shards_list:
        for mode in eval_modes:
            best = None
            for _ in range(max(1, runs)):
                res = _expire_once(mode, backend, shards, n_rounds,
                                   n_sensors)
                if (best is None
                        or res["reinfer_total_s"] < best["reinfer_total_s"]):
                    best = res
            out.append(best)
    return out


def summarize_streaming_expire(rows: list) -> dict:
    """Cross-run acceptance summary: one checksum for every
    (mode, shards) combination, delta-vs-full speedup per shard count,
    and the steady-state full-eval count for the delta runs (must be 0
    — the exit criterion for signed delta frontiers)."""
    checks = {r["checksum"] for r in rows}
    by = {(r["mode"], r["shards"]): r for r in rows}
    shard_counts = sorted({r["shards"] for r in rows})
    speedups = {}
    for s in shard_counts:
        f, d = by.get(("full", s)), by.get(("delta", s))
        if f and d:
            speedups[str(s)] = (f["reinfer_total_s"]
                                / max(d["reinfer_total_s"], 1e-9))
    steady = sum(x["full_evals"]
                 for r in rows if r["mode"] == "delta"
                 for x in r["rounds"][1:])
    return {"bit_identical": len(checks) == 1,
            "delta_vs_full_speedup": speedups,
            "steady_full_evals": steady,
            "neg_passes": sum(x["neg_passes"]
                              for r in rows if r["mode"] == "delta"
                              for x in r["rounds"])}


def bench_sharded(shards: int = 8, scale: int = 1, backend: str = "jax",
                  smoke: bool = False, n_rounds: int = 2, batch: int = 40):
    """Sharded semi-naive fixpoint (``EngineConfig(shards=N)``) vs the
    unsharded engine on the same lubm-like closure + streaming appends.

    The acceptance contract: bit-identical decoded-fact checksums, per-
    shard resident bytes ~1/N of the single-shard table, and frontier
    all-to-all payloads that scale with the append delta, not the table.
    On the CPU container (forced host devices) there is no wall-clock
    win to claim — ``critical_path_s`` (max per-shard seconds per round)
    is the honest scaling signal, wall time is reported as-is.
    """
    import dataclasses

    from repro.core.sharded import decoded_fact_checksum

    facts = lubm_like(1 if smoke else scale)
    if smoke:
        facts = facts[:1500]
    held = n_rounds * batch
    base, stream = facts[:-held], facts[-held:]
    batches = [stream[i * batch:(i + 1) * batch] for i in range(n_rounds)]

    def one(n_shards: int) -> dict:
        cfg = dataclasses.replace(EngineConfig.infer1(backend),
                                  shards=n_shards)
        e = HiperfactEngine(cfg)
        e.add_rules(rdfs_plus_rules())
        t0 = time.perf_counter()
        e.insert_facts(base)
        load_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        st = e.infer()
        infer_s = time.perf_counter() - t0
        sharded = n_shards > 1
        row = {"shards": n_shards, "load_s": load_s, "infer_s": infer_s,
               "inferred": st.facts_inferred,
               "n_facts": (e.num_facts() if sharded
                           else e.store.num_facts()),
               "checksum": decoded_fact_checksum(e)}
        if sharded:
            row["exchange_device"] = e.exchange.device
            row["shard_bytes"] = e.shard_bytes()
            row["resident_facts"] = e.resident_facts()
            row["critical_path_s"] = sum(
                r["critical_path_s"] for r in st.rounds)
            row["infer_rounds"] = [
                {k: r[k] for k in ("round", "critical_path_s", "a2a_rows",
                                   "a2a_payload_bytes", "a2a_padded_bytes",
                                   "a2a_bytes_raw", "a2a_bytes_wire",
                                   "applied_fresh") if k in r}
                for r in st.rounds]
            row["a2a_bytes_raw"] = sum(
                r.get("a2a_bytes_raw", 0) for r in st.rounds)
            row["a2a_bytes_wire"] = sum(
                r.get("a2a_bytes_wire", 0) for r in st.rounds)
        else:
            row["store_bytes"] = e.store.memory_bytes()
        append_rounds = []
        for b in batches:
            e.insert_facts(b)
            t0 = time.perf_counter()
            st = e.infer()
            dt = time.perf_counter() - t0
            r = {"infer_s": dt, "inferred": st.facts_inferred}
            if sharded:
                r["a2a_rows"] = sum(x["a2a_rows"] for x in st.rounds)
                r["a2a_payload_bytes"] = sum(
                    x["a2a_payload_bytes"] for x in st.rounds)
                r["a2a_bytes_raw"] = sum(
                    x.get("a2a_bytes_raw", 0) for x in st.rounds)
                r["a2a_bytes_wire"] = sum(
                    x.get("a2a_bytes_wire", 0) for x in st.rounds)
                r["critical_path_s"] = sum(
                    x["critical_path_s"] for x in st.rounds)
            append_rounds.append(r)
        row["append_rounds"] = append_rounds
        row["final_checksum"] = decoded_fact_checksum(e)
        return row

    rows = [one(1), one(shards)]
    r1, rN = rows
    table_bytes = sum(rN["shard_bytes"])
    rows_out = {
        "backend": backend, "facts_loaded": len(base),
        "runs": rows,
        "bit_identical": (r1["checksum"] == rN["checksum"]
                          and r1["final_checksum"] == rN["final_checksum"]),
        # capacity scaling: the largest shard holds a fraction of the
        # single-node table (views + round-capacity overheads included)
        "max_shard_fraction": (max(rN["shard_bytes"]) /
                               max(r1["store_bytes"], 1)),
        # O(Δ) traffic: append-round a2a bytes vs the resident payload
        "append_a2a_bytes": [r["a2a_payload_bytes"]
                             for r in rN["append_rounds"]],
        "resident_payload_bytes": table_bytes,
        # wire-format mirror of the a2a traffic (frame-of-reference lane
        # narrowing in distributed/compression.py; equal to raw when off)
        "a2a_bytes_raw": (rN.get("a2a_bytes_raw", 0)
                          + sum(r.get("a2a_bytes_raw", 0)
                                for r in rN["append_rounds"])),
        "a2a_bytes_wire": (rN.get("a2a_bytes_wire", 0)
                           + sum(r.get("a2a_bytes_wire", 0)
                                 for r in rN["append_rounds"])),
    }
    return rows_out


def _chain_facts(k_chains: int, length: int):
    """K disjoint edge chains of L hops — the cold-store point-query
    workload.  The full closure is O(K * L^2) path facts while the
    demanded cone of one chain head is O(L^2), so the demand-vs-full
    gap widens linearly with the number of untouched chains."""
    from repro.core.facts import Fact
    return [Fact("edge", f"c{k}_n{i}", "to", f"c{k}_n{i + 1}")
            for k in range(k_chains) for i in range(length)]


def _closure_rules():
    from repro.core.conditions import AddAction, Rule, cond, term
    return [
        Rule("base", (cond("edge", "?x", "to", "?y"),),
             (AddAction("path", term("?x"), "to", term("?y")),)),
        Rule("rec", (cond("edge", "?x", "to", "?y"),
                     cond("path", "?y", "to", "?z")),
             (AddAction("path", term("?x"), "to", term("?z")),)),
    ]


def _result_checksum(rows: list) -> int:
    """Order-insensitive digest of decoded query rows — demand-vs-full
    parity must hold on the result *set*."""
    import zlib
    return zlib.crc32(repr(sorted(tuple(sorted(r.items()))
                                  for r in rows)).encode())


def bench_demand(backend: str = "numpy", smoke: bool = False,
                 shards: int = 1, requery_reps: int = 50) -> dict:
    """Cold-store point query: demand transformation vs full closure.

    Two engines over the same K-chain edge store, both *cold* (no
    ``infer()`` before the query).  The ``full`` engine materializes the
    whole closure then queries; the ``demand`` engine (with the sketch
    planner on) routes ``query()`` through the magic-set cone and only
    materializes the queried chain.  Acceptance: identical decoded
    results (checksums), demand ``rows_considered`` a small fraction of
    full (<10% at the non-smoke size), and a re-query at fixed versions
    that stays zero-transfer with sketches cached.  The re-query loop
    also times the query-cache hit path — entries are frozen row tuples
    now, so each hit pays exactly one ``dict()`` copy per row."""
    import dataclasses

    from repro.core.conditions import cond

    k_chains, length = (6, 8) if smoke else (20, 20)
    facts = _chain_facts(k_chains, length)
    q = [cond("path", "c0_n0", "to", "?z")]
    out = {"backend": backend, "shards": shards, "facts": len(facts),
           "chains": k_chains, "chain_len": length}

    # full-closure comparator: infer() then query
    cfg = dataclasses.replace(EngineConfig.infer1(backend),
                              eval_mode="full", shards=shards)
    e = HiperfactEngine(cfg)
    e.add_rules(_closure_rules())
    e.insert_facts(facts)
    t0 = time.perf_counter()
    e.infer()
    rows_full = e.query(q)
    full_s = time.perf_counter() - t0
    out["full"] = {"query_s": full_s,
                   "rows_considered": e.last_infer.rows_considered,
                   "inferred": e.last_infer.facts_inferred,
                   "rows": len(rows_full),
                   "checksum": _result_checksum(rows_full)}

    # demand engine: query() materializes the cone on first touch
    cfg = dataclasses.replace(EngineConfig.infer1(backend),
                              eval_mode="demand", sort_mode="sketch",
                              shards=shards)
    e = HiperfactEngine(cfg)
    ops = getattr(e, "ops", None)
    tc = getattr(ops, "transfers", None) if ops is not None else None
    e.add_rules(_closure_rules())
    e.insert_facts(facts)
    t0 = time.perf_counter()
    rows_dem = e.query(q)
    demand_s = time.perf_counter() - t0
    st = e.last_infer
    out["demand"] = {"query_s": demand_s,
                     "rows_considered": st.rows_considered,
                     "cone_rows": st.demand_cone_rows,
                     "rounds": st.demand_rounds,
                     "fallbacks": st.demand_fallbacks,
                     "replans": st.replans,
                     "sketch_hits": st.sketch_hits,
                     "sketch_misses": st.sketch_misses,
                     "rows": len(rows_dem),
                     "checksum": _result_checksum(rows_dem)}
    out["bit_identical"] = (out["full"]["checksum"]
                            == out["demand"]["checksum"])
    out["rows_considered_ratio"] = (
        out["demand"]["rows_considered"]
        / max(out["full"]["rows_considered"], 1))

    # re-query at fixed versions: served by the query cache (single-copy
    # hit path) without re-entering demand or evaluation; on device
    # backends also assert zero transfer with sketches cached
    snap = tc.snapshot() if tc is not None else None
    t0 = time.perf_counter()
    for _ in range(max(1, requery_reps)):
        rows_re = e.query(q)
    requery = {"reps": max(1, requery_reps),
               "per_query_s": ((time.perf_counter() - t0)
                               / max(1, requery_reps)),
               "checksum": _result_checksum(rows_re),
               "note": "cache stores frozen row tuples; each hit pays "
                       "one dict() copy per row (was two copies)"}
    if tc is not None:
        d = tc.delta(snap)
        requery["transfer_bytes"] = d.h2d_bytes + d.d2h_bytes
    out["requery"] = requery
    return out


def bench_serving(backend: str = "numpy", smoke: bool = False,
                  shards: int = 1, writers: int = 2,
                  readers: int = 4) -> dict:
    """Concurrent fact-serving tier (ISSUE 10): FactServer QPS + parity.

    Three sub-benchmarks over the K-chain closure store:

    * ``mixed`` — ``writers`` append/delete threads against ``readers``
      query threads; every served result is checked against a
      single-threaded oracle replay of the write prefix behind its
      snapshot token (``checksum_ok``), and any served token outside
      the write history counts as a torn read.
    * ``requery`` — steady-state delta-aware requery: after the warm
      build, each append + requery round must run **zero** full
      evaluations (signed ±frontier folds only).
    * ``batching`` — cross-request coalescing of rank-1 point queries:
      queries per device call at p50 must be >= 2.
    """
    import dataclasses
    import threading

    from repro.core.conditions import cond
    from repro.serve import FactServer

    k_chains, length = (4, 6) if smoke else (8, 8)
    w_ops, r_ops = (10, 25) if smoke else (25, 60)
    out = {"backend": backend, "shards": shards,
           "chains": k_chains, "chain_len": length}

    def build():
        cfg = dataclasses.replace(EngineConfig.infer1(backend),
                                  eval_mode="delta", shards=shards)
        e = HiperfactEngine(cfg)
        e.add_rules(_closure_rules())
        e.insert_facts(_chain_facts(k_chains, length))
        e.infer()
        return e

    def rows_key(rows):
        return tuple(sorted(tuple(sorted(r.items())) for r in rows))

    from repro.core.facts import Fact
    point_q = [cond("edge", "c0_n0", "to", "?y")]          # batched route
    join_q = [cond("edge", "?x", "to", "?y"),              # eval route
              cond("path", "?y", "to", "?z")]

    # ---- mixed append+query workload -----------------------------------
    lat: list = []
    served: list = []
    lock = threading.Lock()
    with FactServer(build(), batch_window=0.001,
                    record_history=True) as srv:
        def writer(w):
            appended = []
            for i in range(w_ops):
                if w == 0 and i % 5 == 4 and appended:
                    srv.delete([appended.pop(0)])
                else:
                    f = Fact("edge", f"w{w}_m{i}", "to", f"w{w}_m{i + 1}")
                    srv.append([f])
                    appended.append(f)

        def reader(r):
            for i in range(r_ops):
                name = "point" if i % 2 else "join"
                t0 = time.perf_counter()
                res = srv.serve(point_q if name == "point" else join_q,
                                tenant=f"t{r}")
                dt = time.perf_counter() - t0
                with lock:
                    lat.append(dt)
                    served.append((name, res))

        threads = ([threading.Thread(target=writer, args=(w,))
                    for w in range(writers)] +
                   [threading.Thread(target=reader, args=(r,))
                    for r in range(readers)])
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        history = list(srv.history)

    known = {tok for _, _, tok in history}
    torn = sum(1 for _, res in served if res.token not in known)
    # oracle: one incremental replay of the history, query at every
    # distinct served token
    last_idx = {}
    for i, (_, _, tok) in enumerate(history):
        last_idx[tok] = i
    oracle = HiperfactEngine(dataclasses.replace(
        EngineConfig.infer1("numpy"), eval_mode="full"))
    oracle.add_rules(_closure_rules())
    oracle.insert_facts(_chain_facts(k_chains, length))
    oracle.infer()
    expect = {}
    for i, (kind, facts, tok) in enumerate(history):
        if facts:
            if kind == "append":
                oracle.insert_facts(facts)
            else:
                oracle.delete_facts(facts)
            oracle.infer()
        if last_idx[tok] == i:
            expect[(tok, "point")] = rows_key(oracle.query(point_q))
            expect[(tok, "join")] = rows_key(oracle.query(join_q))
    checksum_ok = torn == 0 and all(
        rows_key(res.rows) == expect[(res.token, name)]
        for name, res in served)
    ms = sorted(x * 1e3 for x in lat)
    out["mixed"] = {"writers": writers, "readers": readers,
                    "ops": writers * w_ops + len(served),
                    "qps": len(served) / max(wall, 1e-9),
                    "p50_ms": ms[len(ms) // 2],
                    "p99_ms": ms[min(len(ms) - 1, int(len(ms) * 0.99))],
                    "checksum_ok": bool(checksum_ok),
                    "torn_reads": torn}

    # ---- steady-state delta requery ------------------------------------
    # single-condition point query: tracked by the engine's query nodes
    # unsharded, and by the per-worker nodes (union route) sharded —
    # each append extends the queried chain so every fold changes the
    # result
    path_q = [cond("path", "c0_n0", "to", "?z")]
    rounds = 5 if smoke else 20
    with FactServer(build(), batching=False) as srv:
        srv.serve(path_q)                     # warm: the one full build
        warm = srv.stats()["requery"]["full_evals"]
        rlat = []
        for i in range(rounds):
            srv.append([Fact("edge", f"c0_n{length + i}", "to",
                             f"c0_n{length + i + 1}")])
            t0 = time.perf_counter()
            srv.serve(path_q)
            rlat.append(time.perf_counter() - t0)
        st = srv.stats()["requery"]
        assert len(srv.serve(path_q).rows) == length + rounds
    rms = sorted(x * 1e3 for x in rlat)
    out["requery"] = {"rounds": rounds,
                      "full_evals": st["full_evals"] - warm,
                      "delta_folds": st["delta_folds"],
                      "p50_ms": rms[len(rms) // 2],
                      "p99_ms": rms[min(len(rms) - 1,
                                        int(len(rms) * 0.99))]}

    # ---- cross-request batching ----------------------------------------
    n_req = 8 if smoke else 16
    with FactServer(build(), batch_window=None, max_batch=n_req) as srv:
        qs = [[cond("edge", f"c{i % k_chains}_n0", "to", "?y")]
              for i in range(n_req)]
        threads = [threading.Thread(target=srv.serve,
                                    args=(qs[i], f"t{i % 4}"))
                   for i in range(n_req)]
        for t in threads:
            t.start()
        deadline = time.time() + 30
        while srv._batcher.queued() < n_req and time.time() < deadline:
            time.sleep(0.001)
        srv.flush_batches()
        for t in threads:
            t.join(timeout=60)
        out["batching"] = srv.stats()["batch"]
    return out


def main(scale: int = 1, backend: str = "numpy"):
    print("dataset,engine,load_s,infer_s,query_s,facts_inferred")
    for dname, ename, r in bench(scale, backend=backend):
        print(f"{dname},{ename},{r['load_s']:.4f},{r['infer_s']:.4f},"
              f"{r['query_s']:.4f},{r['inferred']}")
        if "transfers" in r:
            print(f"#   {ename}: {fmt_transfers(r['transfers'])} "
                  f"cache={r['cache']}")


if __name__ == "__main__":
    import sys
    main(scale=int(sys.argv[1]) if len(sys.argv) > 1 else 1,
         backend=sys.argv[2] if len(sys.argv) > 2 else "numpy")
