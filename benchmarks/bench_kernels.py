"""Fork-join kernel microbenchmarks (paper §2.3 instances).

On this CPU container the Pallas kernels only run under interpret=True
(not a performance mode), so wall-times compare the *portable jitted XLA
paths* against host numpy; the Pallas kernels are timed in interpret mode
purely to confirm they execute (correctness lives in tests/test_kernels).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.backend import get_backend
from repro.kernels.mergejoin.ops import merge_join_bounded
from repro.kernels.sortmerge.ops import device_sort
from repro.kernels.uniquefilter.ops import unique_sorted_bounded
from repro.core.joins import merge_join_pairs


def timeit(fn, *args, repeats=5):
    fn(*args)  # warm/compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") or \
        isinstance(out, (tuple, list)) else None
    return (time.perf_counter() - t0) / repeats


def bench(n: int = 1 << 16):
    rng = np.random.RandomState(0)
    x = rng.randint(0, 1 << 30, n).astype(np.int64)
    xj = jnp.asarray(x)
    rows = []

    rows.append(("sort_numpy", timeit(lambda: np.sort(x))))
    rows.append(("sort_xla_jit", timeit(lambda: device_sort(xj))))
    rows.append(("sort_pallas_interpret",
                 timeit(lambda: device_sort(xj[: 1 << 12],
                                            force_pallas=True,
                                            interpret=True), repeats=1)))

    l = rng.randint(0, n // 4, n // 2).astype(np.int64)
    r = rng.randint(0, n // 4, n // 2).astype(np.int64)
    lj, rj = jnp.asarray(l), jnp.asarray(r)
    rows.append(("join_numpy", timeit(lambda: merge_join_pairs(l, r))))
    rows.append(("join_xla_jit",
                 timeit(lambda: merge_join_bounded(lj, rj, out_cap=1 << 18))))

    rows.append(("unique_numpy", timeit(lambda: np.unique(x))))
    rows.append(("unique_xla_jit",
                 timeit(lambda: unique_sorted_bounded(xj))))
    return rows


def bench_backends(n: int = 1 << 15, names=("numpy", "jax")):
    """Ops-layer comparison: the same primitives the engine hot path issues,
    per execution backend (acceptance: report both backends)."""
    rng = np.random.RandomState(1)
    keys = rng.randint(0, 1 << 30, n).astype(np.int64)
    vals = np.arange(n, dtype=np.int64)
    l = rng.randint(0, n // 4, n // 2).astype(np.int64)
    r = rng.randint(0, n // 4, n // 2).astype(np.int64)
    bound = rng.randint(0, n // 4, n // 8).astype(np.int64)
    cols = [rng.randint(0, 64, n).astype(np.int64) for _ in range(3)]
    # same span, forced past the tagged-width guard: exercises the XLA
    # stable-lexsort fallback for a before/after on the dedup change
    wide = [c.copy() for c in cols]
    wide[0][0] = np.iinfo(np.int64).max // 2
    wide[0][1] = np.iinfo(np.int64).min // 2
    rows = []
    for name in names:
        ops = get_backend(name)
        rows.append((f"backend[{name}]_sort_kv",
                     timeit(lambda: ops.sort_kv(keys, vals))))
        rows.append((f"backend[{name}]_sort_perm",
                     timeit(lambda: ops.sort_perm(keys))))
        rows.append((f"backend[{name}]_join_pairs",
                     timeit(lambda: ops.join_pairs(l, r))))
        rows.append((f"backend[{name}]_hash_join",
                     timeit(lambda: ops.hash_join_pairs(l, r))))
        rows.append((f"backend[{name}]_semi_join",
                     timeit(lambda: ops.semi_join(l, bound))))
        rows.append((f"backend[{name}]_dedup_rows_tagged",
                     timeit(lambda: ops.dedup_rows(cols))))
        rows.append((f"backend[{name}]_dedup_rows_widekeys",
                     timeit(lambda: ops.dedup_rows(wide))))
    return rows


def bench_residency(n: int = 1 << 14, batches: int = 16,
                    batch: int = 512):
    """Device residency: an append-heavy index-build loop with and without
    the version cache.  Reports wall time, host->device bytes (the cached
    loop uploads only each appended tail), and the sort-work split — the
    cached loop *merge-maintains* the resident mirror, so per-append sort
    bytes are the delta bucket (``merged_bytes``) instead of the whole
    column (``sorted_bytes``)."""
    from repro.backend.jax_ops import JaxOps

    rng = np.random.RandomState(2)
    col = rng.randint(0, 1 << 30, n + batches * batch).astype(np.int64)
    rows = []
    for label, cached in (("cold", False), ("resident", True)):
        ops = JaxOps(mode="auto")
        t0 = time.perf_counter()
        for i in range(batches):
            cur = col[: n + (i + 1) * batch]
            kw = ({"cache_key": ("bench", 0), "version": i}
                  if cached else {})
            ops.sort_perm(cur, **kw)
        dt = (time.perf_counter() - t0) / batches
        rows.append((f"residency[{label}]_sort_perm", dt))
        rows.append((f"residency[{label}]_h2d_bytes",
                     ops.transfers.h2d_bytes))
        rows.append((f"residency[{label}]_sorted_bytes",
                     ops.sort_work.sorted_bytes))
        rows.append((f"residency[{label}]_merged_bytes",
                     ops.sort_work.merged_bytes))
        if cached:
            st = ops.residency_stats()
            rows.append((f"residency[{label}]_resident_bytes_raw",
                         st["resident_bytes_raw"]))
            rows.append((f"residency[{label}]_resident_bytes_coded",
                         st["resident_bytes_coded"]))
    return rows


def bench_compression(n: int = 1 << 15):
    """Compressed device-resident columns on a lubm-like column mix:
    dense entity ids (frame-of-reference), low-cardinality wide interned
    predicate values (dictionary), and a grouped derived column (RLE).
    Uploads the same columns with compression off and on, decodes both
    back, and reports the resident footprint split plus the per-codec
    counters — the decoded checksums must be bit-identical."""
    import zlib

    from repro.backend.jax_ops import JaxOps

    rng = np.random.RandomState(5)
    preds = (np.arange(24, dtype=np.uint64)
             * np.uint64(0x9E3779B97F4A7C15)).astype(np.int64) >> 1
    cols = {
        "id": (10**9 + rng.randint(0, 4 * n, n)).astype(np.int64),
        "attr": preds[rng.randint(0, len(preds), n)],
        "derived": np.repeat(
            np.arange(max(1, n // 64), dtype=np.int64) * 10**10, 64)[:n],
    }
    out = {"n_facts": n, "runs": []}
    for label, compress in (("raw", False), ("coded", True)):
        ops = JaxOps(mode="auto", compress=compress)
        t0 = time.perf_counter()
        cks = 0
        for name, col in cols.items():
            h = ops.upload_resident(("lubm", name), 1, col)
            dec = np.asarray(h.data)[:h.n]
            cks = zlib.crc32(np.ascontiguousarray(dec).tobytes(), cks)
        st = ops.residency_stats()
        out["runs"].append({
            "label": label, "compress": compress,
            "upload_s": time.perf_counter() - t0,
            "checksum": cks,
            "resident_bytes_raw": st["resident_bytes_raw"],
            "resident_bytes_coded": st["resident_bytes_coded"],
            "codecs": st["codecs"],
        })
    raw_run, coded_run = out["runs"]
    out["bit_identical"] = raw_run["checksum"] == coded_run["checksum"]
    out["bytes_per_fact_raw"] = raw_run["resident_bytes_coded"] / n
    out["bytes_per_fact_coded"] = coded_run["resident_bytes_coded"] / n
    out["ratio"] = (out["bytes_per_fact_raw"]
                    / max(out["bytes_per_fact_coded"], 1e-9))
    return out


def bench_batch_probe(n: int = 1 << 14, n_probes: int = 2048,
                      backend: str = "jax"):
    """Batched rank-1 probes (`FactStore.lookup_many`) vs the per-probe
    loop — the ROADMAP's 'revisit with batched probes' item."""
    from repro.core import EngineConfig, HiperfactEngine
    from repro.core.store import Component

    rng = np.random.RandomState(3)
    e = HiperfactEngine(EngineConfig(index_backend="AI", backend=backend))
    e.insert_columns("T", rng.randint(0, n // 4, n),
                     rng.randint(0, 64, n),
                     rng.randint(0, 1 << 30, n),
                     np.zeros(n, np.int8))
    t = e.store.tables["T"]
    probes = rng.randint(0, n // 4, n_probes).astype(np.int64)
    rows = []

    def loop():
        return [t.index.lookup(t, Component.ID, int(v)) for v in probes]

    def batched():
        return e.store.lookup_many("T", Component.ID, probes)

    rows.append((f"probe[{backend}]_per_probe_loop", timeit(loop)))
    rows.append((f"probe[{backend}]_batched", timeit(batched)))
    return rows


def main():
    print("kernel,seconds_per_call")
    for name, s in bench():
        print(f"{name},{s:.5f}")
    for name, s in bench_backends():
        print(f"{name},{s:.5f}")
    for name, s in bench_residency():
        print(f"{name},{s}")
    for name, s in bench_batch_probe():
        print(f"{name},{s:.5f}")


if __name__ == "__main__":
    main()
