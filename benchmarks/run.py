"""Benchmark entry point: one section per paper table + the roofline.

    PYTHONPATH=src python -m benchmarks.run [--full]

Default sizes are CPU-container friendly (~2-4 min); --full scales the
datasets up (the paper's LUBM50/100-class sizes).
"""

from __future__ import annotations

import argparse
import os
import time


def section(title: str):
    print(f"\n==== {title} " + "=" * max(0, 60 - len(title)), flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--backend", default="numpy",
                    choices=["numpy", "jax", "jax-pallas", "jax-interpret"],
                    help="execution backend for the engine hot path "
                         "(see src/repro/backend/README.md)")
    args = ap.parse_args()

    t_start = time.perf_counter()

    section(f"Table 2 analog: inference (backend={args.backend})")
    from benchmarks import bench_inference
    scale = 8 if args.full else 1
    for dname, ename, r in bench_inference.bench(scale=scale,
                                                 backend=args.backend):
        print(f"{dname},{ename},load={r['load_s']:.4f}s,"
              f"infer={r['infer_s']:.4f}s,query={r['query_s']:.4f}s,"
              f"inferred={r['inferred']}")

    section(f"Table 4 analog: query config matrix (backend={args.backend})")
    from benchmarks import bench_query
    kw = {} if not args.full else {
        "mondial_kw": {"n_countries": 60, "cities_per": 120},
        "dblp_kw": {"n_papers": 20000, "n_authors": 3000}}
    for dname, label, r in bench_query.bench(backend=args.backend, **kw):
        print(f"{dname},{label},load={r['load_s']:.4f}s,"
              f"query={r['query_s']:.6f}s")

    section("Hiperfact vs Rete scaling")
    from benchmarks import bench_vs_rete
    for s, hf, rete in bench_vs_rete.bench(
            scales=(1, 2, 4) if not args.full else (1, 4, 8)):
        sp = rete["infer_s"] / max(hf["infer_s"], 1e-9)
        print(f"scale={s},facts={hf['n_facts']},"
              f"hiperfact={hf['infer_s']:.4f}s,rete={rete['infer_s']:.4f}s,"
              f"speedup={sp:.1f}x")

    section("Island processing internals (AR/DR, sort keys, island order)")
    from benchmarks import bench_islands
    for label, dt, n in bench_islands.bench_rnl_modes():
        print(f"{label},{dt:.5f}s,rows={n}")
    for label, dt in bench_islands.bench_island_order():
        print(f"{label},{dt:.5f}s")

    section("Fork-join kernel micro (portable XLA paths)")
    from benchmarks import bench_kernels
    for name, s in bench_kernels.bench():
        print(f"{name},{s:.5f}s")
    # Ops-layer comparison: numpy vs device backend on the same primitives
    for name, s in bench_kernels.bench_backends(
            names=("numpy", args.backend if args.backend != "numpy"
                   else "jax")):
        print(f"{name},{s:.5f}s")

    section("Extensions (paper §5): rank-N query cache + CR compression")
    from benchmarks import bench_extensions
    for label, dt, hr in bench_extensions.bench_query_cache():
        print(f"query-cache,{label},{dt:.5f}s,hit_rate={hr:.2f}")
    for name, codec, ratio, enc_s in bench_extensions.bench_compression():
        print(f"compression,{name},{codec},{ratio:.1f}x,{enc_s:.4f}s")

    section("Roofline (from dry-run artifacts, if present)")
    from benchmarks import roofline
    for d in ("out/dryrun/single", "out/dryrun/multi"):
        if os.path.isdir(d) and os.listdir(d):
            print(f"-- {d}")
            rows = roofline.report(roofline.load(d))
            for r in rows:
                print(f"{r['cell']},bound={r['bottleneck']},"
                      f"compute={r['compute_s']:.4f}s,"
                      f"memory={r['memory_s']:.4f}s,"
                      f"collective={r['collective_s']:.4f}s,"
                      f"useful={100*r['useful_ratio']:.1f}%,"
                      f"roofline={100*r['roofline_frac']:.2f}%")
        else:
            print(f"-- {d}: no artifacts (run repro.launch.dryrun first)")

    print(f"\nall benches done in {time.perf_counter() - t_start:.1f}s")


if __name__ == "__main__":
    main()
