"""Benchmark entry point: one section per paper table + the roofline.

    PYTHONPATH=src python -m benchmarks.run [--full] [--smoke]
                                            [--backend B] [--json PATH]

Default sizes are CPU-container friendly (~2-4 min); --full scales the
datasets up (the paper's LUBM50/100-class sizes); --smoke shrinks to
CI-sized inputs (inference presets + kernel micro only).

--json writes a machine-readable snapshot (op timings, transfer counts,
h2d bytes, cache stats) so the perf trajectory is tracked across PRs —
the convention is ``BENCH_<pr>.json`` at the repo root.
"""

from __future__ import annotations

import argparse
import json
import os
import time


def section(title: str):
    print(f"\n==== {title} " + "=" * max(0, 60 - len(title)), flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized inputs: inference presets + kernel "
                         "micro only")
    ap.add_argument("--backend", default="numpy",
                    choices=["numpy", "jax", "jax-pallas", "jax-interpret"],
                    help="execution backend for the engine hot path "
                         "(see src/repro/backend/README.md)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write a machine-readable snapshot "
                         "(BENCH_<pr>.json convention)")
    ap.add_argument("--eval-mode", default=None,
                    choices=["full", "delta", "auto", "demand"],
                    help="force the demand section on under --smoke "
                         "(demand) — non-smoke runs always include it")
    ap.add_argument("--serve", action="store_true",
                    help="force the serving section on under --smoke — "
                         "non-smoke runs always include it")
    ap.add_argument("--writers", type=int, default=2, metavar="N",
                    help="writer threads for the serving section")
    ap.add_argument("--readers", type=int, default=4, metavar="N",
                    help="reader threads for the serving section")
    ap.add_argument("--shards", type=int, default=0, metavar="N",
                    help="also bench the sharded fixpoint "
                         "(EngineConfig(shards=N) vs shards=1); forces "
                         "N host devices via XLA_FLAGS when no real "
                         "device mesh is configured")
    args = ap.parse_args()

    if args.shards > 1 and "xla_force_host_platform_device_count" \
            not in os.environ.get("XLA_FLAGS", ""):
        # must happen before the first jax import in this process
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.shards}").strip()

    t_start = time.perf_counter()
    report: dict = {"backend": args.backend, "smoke": args.smoke,
                    "full": args.full, "sections": {}}

    section(f"Table 2 analog: inference (backend={args.backend})")
    from benchmarks import bench_inference
    scale = 8 if args.full else 1
    # device backends: best-of-2 so one-time jit compilation doesn't
    # masquerade as steady-state cost in the snapshot
    runs = 2 if args.backend != "numpy" and not args.smoke else 1
    inf_rows = bench_inference.bench(scale=scale, backend=args.backend,
                                     smoke=args.smoke, runs=runs)
    report["sections"]["inference"] = [
        {"dataset": d, "engine": e, **r} for d, e, r in inf_rows]
    for dname, ename, r in inf_rows:
        print(f"{dname},{ename},load={r['load_s']:.4f}s,"
              f"infer={r['infer_s']:.4f}s,query={r['query_s']:.4f}s,"
              f"requery={r.get('requery_s', 0):.5f}s,"
              f"inferred={r['inferred']}")
        if "transfers" in r:
            sw = (" " + bench_inference.fmt_sort_work(r["sort_work"])
                  if "sort_work" in r else "")
            print(f"#   {ename}: "
                  f"{bench_inference.fmt_transfers(r['transfers'])}{sw} "
                  f"cache={r['cache']}")

    section(f"Streaming appends: semi-naive delta vs full "
            f"(backend={args.backend})")
    # --smoke keeps the delta path exercised on every CI push (small
    # scale, eval_mode=delta included) — see ISSUE 4 / backend README
    stream_scale = 2 if args.smoke else (16 if args.full else 8)
    stream_rows = bench_inference.bench_streaming(
        scale=stream_scale, backend=args.backend,
        n_rounds=2 if args.smoke else 4)
    report["sections"]["streaming"] = stream_rows
    by_mode = {r["mode"]: r for r in stream_rows}
    for r in stream_rows:
        per_round = ",".join(f"{x['infer_s']:.4f}s" for x in r["rounds"])
        xfer = ""
        if "h2d_bytes" in r["rounds"][0]:
            xfer = (" h2d=" + ",".join(str(x["h2d_bytes"])
                                       for x in r["rounds"]))
        if "merged_bytes" in r["rounds"][0]:
            # incremental index maintenance: merged (delta-run) vs full
            # re-sort bytes per append round
            xfer += (" sorted=" + ",".join(str(x["sorted_bytes"])
                                           for x in r["rounds"]) +
                     " merged=" + ",".join(str(x["merged_bytes"])
                                           for x in r["rounds"]))
        print(f"eval_mode={r['mode']},initial={r['initial_infer_s']:.4f}s,"
              f"reinfer=[{per_round}],facts={r['n_facts']},"
              f"checksum={r['checksum']}{xfer}")
        if "cache" in r:
            print(f"#   cache={r['cache']}")
    if {"full", "delta"} <= by_mode.keys():
        f, d = by_mode["full"], by_mode["delta"]
        ok = (f["checksum"] == d["checksum"]) and (f["n_facts"] == d["n_facts"])
        sp = f["reinfer_total_s"] / max(d["reinfer_total_s"], 1e-9)
        # steady state excludes the first round: a fresh engine's first
        # delta round pays one-time residency warm-up (uploads + index
        # mirrors), which a long-lived streaming engine never repeats
        steady_f = sum(x["infer_s"] for x in f["rounds"][1:])
        steady_d = sum(x["infer_s"] for x in d["rounds"][1:])
        sps = steady_f / max(steady_d, 1e-9)
        report["sections"]["streaming_summary"] = {
            "bit_identical": ok, "reinfer_speedup": sp,
            "steady_reinfer_speedup": sps}
        print(f"delta-vs-full: bit_identical={ok},reinfer_speedup={sp:.1f}x,"
              f"steady={sps:.1f}x")

    section(f"Streaming expiry: signed delta frontiers vs full "
            f"(backend={args.backend})")
    # append + bulk-expire rounds (IoT threshold rules): deletes must
    # ride O(Δ) negative passes — see ISSUE 7 / docs/ARCHITECTURE.md
    exp_shards = (1,) if args.smoke else (1, 4)
    exp_rows = bench_inference.bench_streaming_expire(
        backend=args.backend, shards_list=exp_shards,
        n_rounds=3 if args.smoke else 4,
        n_sensors=60 if args.smoke else 120,
        runs=1 if args.smoke else 2)
    exp_sum = bench_inference.summarize_streaming_expire(exp_rows)
    report["sections"]["streaming_expire"] = {
        "runs": exp_rows, **exp_sum}
    for r in exp_rows:
        per = ",".join(f"{x['append_infer_s'] + x['expire_infer_s']:.4f}s"
                       for x in r["rounds"])
        fe = ",".join(str(x["full_evals"]) for x in r["rounds"])
        neg = ",".join(str(x["neg_passes"]) for x in r["rounds"])
        print(f"eval_mode={r['mode']},shards={r['shards']},"
              f"rounds=[{per}],full_evals=[{fe}],neg_passes=[{neg}],"
              f"facts={r['n_facts']},checksum={r['checksum']}")
    exp_sp = {k: round(v, 1)
              for k, v in exp_sum["delta_vs_full_speedup"].items()}
    print(f"expire delta-vs-full: bit_identical={exp_sum['bit_identical']},"
          f"speedup={exp_sp},"
          f"steady_full_evals={exp_sum['steady_full_evals']}")

    if args.shards > 1:
        section(f"Sharded fixpoint: {args.shards}-way hash partition + "
                f"frontier all-to-all")
        sh = bench_inference.bench_sharded(
            shards=args.shards, scale=2 if args.full else 1,
            smoke=args.smoke)
        report["sections"]["sharded"] = sh
        for r in sh["runs"]:
            extra = ""
            if r["shards"] > 1:
                a2a = ",".join(str(x["a2a_payload_bytes"])
                               for x in r["append_rounds"])
                extra = (f",device={r['exchange_device']},"
                         f"critical_path={r['critical_path_s']:.4f}s,"
                         f"max_shard_b={max(r['shard_bytes'])},"
                         f"append_a2a_b=[{a2a}]")
            print(f"shards={r['shards']},load={r['load_s']:.4f}s,"
                  f"infer={r['infer_s']:.4f}s,facts={r['n_facts']},"
                  f"checksum={r['checksum']}{extra}")
        print(f"bit_identical={sh['bit_identical']},"
              f"max_shard_fraction={sh['max_shard_fraction']:.3f},"
              f"append_a2a_bytes={sh['append_a2a_bytes']},"
              f"resident_payload_bytes={sh['resident_payload_bytes']},"
              f"a2a_bytes_raw={sh.get('a2a_bytes_raw', 0)},"
              f"a2a_bytes_wire={sh.get('a2a_bytes_wire', 0)}")

    if not args.smoke or args.eval_mode == "demand":
        section(f"Demand-driven evaluation: cold-store point query "
                f"(backend={args.backend})")
        # magic-set cone vs full closure — see ISSUE 9 /
        # docs/ARCHITECTURE.md §Demand-driven evaluation
        dem = bench_inference.bench_demand(
            backend=args.backend, smoke=args.smoke,
            shards=max(1, args.shards))
        report["sections"]["demand"] = dem
        f, d = dem["full"], dem["demand"]
        print(f"full,query={f['query_s']:.4f}s,"
              f"rows_considered={f['rows_considered']},"
              f"inferred={f['inferred']},rows={f['rows']}")
        print(f"demand,query={d['query_s']:.4f}s,"
              f"rows_considered={d['rows_considered']},"
              f"cone_rows={d['cone_rows']},rounds={d['rounds']},"
              f"sketch={d['sketch_hits']}h/{d['sketch_misses']}m,"
              f"replans={d['replans']},rows={d['rows']}")
        rq = dem["requery"]
        xfer = (f",transfer_bytes={rq['transfer_bytes']}"
                if "transfer_bytes" in rq else "")
        print(f"requery,per_query={rq['per_query_s'] * 1e6:.1f}us"
              f"{xfer}")
        print(f"bit_identical={dem['bit_identical']},"
              f"rows_considered_ratio={dem['rows_considered_ratio']:.3f}")

    if not args.smoke or args.serve:
        section(f"Fact serving: concurrent writers + snapshot-isolated "
                f"readers (backend={args.backend})")
        # FactServer QPS + oracle parity — see ISSUE 10 /
        # docs/ARCHITECTURE.md §Serving tier
        sv = bench_inference.bench_serving(
            backend=args.backend, smoke=args.smoke,
            shards=max(1, args.shards), writers=args.writers,
            readers=args.readers)
        report["sections"]["serving"] = sv
        m = sv["mixed"]
        print(f"mixed,writers={m['writers']},readers={m['readers']},"
              f"ops={m['ops']},qps={m['qps']:.1f},"
              f"p50={m['p50_ms']:.2f}ms,p99={m['p99_ms']:.2f}ms,"
              f"checksum_ok={m['checksum_ok']},"
              f"torn_reads={m['torn_reads']}")
        rq = sv["requery"]
        print(f"requery,rounds={rq['rounds']},"
              f"full_evals={rq['full_evals']},"
              f"delta_folds={rq['delta_folds']},"
              f"p50={rq['p50_ms']:.2f}ms,p99={rq['p99_ms']:.2f}ms")
        b = sv["batching"]
        print(f"batching,device_calls={b['device_calls']},"
              f"batched_queries={b['batched_queries']},"
              f"coalesce_p50={b['coalesce_p50']:.1f},"
              f"coalesce_mean={b['coalesce_mean']:.2f}")

    if not args.smoke:
        section(f"Table 4 analog: query config matrix "
                f"(backend={args.backend})")
        from benchmarks import bench_query
        kw = {} if not args.full else {
            "mondial_kw": {"n_countries": 60, "cities_per": 120},
            "dblp_kw": {"n_papers": 20000, "n_authors": 3000}}
        q_rows = bench_query.bench(backend=args.backend, **kw)
        report["sections"]["query"] = [
            {"dataset": d, "config": c, **r} for d, c, r in q_rows]
        for dname, label, r in q_rows:
            print(f"{dname},{label},load={r['load_s']:.4f}s,"
                  f"query={r['query_s']:.6f}s")

        section("Hiperfact vs Rete scaling")
        from benchmarks import bench_vs_rete
        rete_rows = []
        for s, hf, rete in bench_vs_rete.bench(
                scales=(1, 2, 4) if not args.full else (1, 4, 8)):
            sp = rete["infer_s"] / max(hf["infer_s"], 1e-9)
            rete_rows.append({"scale": s, "facts": hf["n_facts"],
                              "hiperfact_s": hf["infer_s"],
                              "rete_s": rete["infer_s"], "speedup": sp})
            print(f"scale={s},facts={hf['n_facts']},"
                  f"hiperfact={hf['infer_s']:.4f}s,"
                  f"rete={rete['infer_s']:.4f}s,speedup={sp:.1f}x")
        report["sections"]["vs_rete"] = rete_rows

        section("Island processing internals (AR/DR, sort keys, order)")
        from benchmarks import bench_islands
        isl = []
        for label, dt, n in bench_islands.bench_rnl_modes():
            isl.append({"label": label, "seconds": dt, "rows": n})
            print(f"{label},{dt:.5f}s,rows={n}")
        for label, dt in bench_islands.bench_island_order():
            isl.append({"label": label, "seconds": dt})
            print(f"{label},{dt:.5f}s")
        report["sections"]["islands"] = isl

    section("Fork-join kernel micro (portable XLA paths)")
    from benchmarks import bench_kernels
    kn = (1 << 12) if args.smoke else (1 << 16)
    bn = (1 << 11) if args.smoke else (1 << 15)
    ops_rows = list(bench_kernels.bench(n=kn))
    ops_rows += bench_kernels.bench_backends(
        n=bn, names=("numpy", args.backend if args.backend != "numpy"
                     else "jax"))
    if not args.smoke:
        ops_rows += bench_kernels.bench_residency()
        ops_rows += bench_kernels.bench_batch_probe(
            backend=args.backend if args.backend != "numpy" else "jax")
    report["sections"]["kernels"] = [
        {"op": name, "value": v} for name, v in ops_rows]
    for name, s in ops_rows:
        print(f"{name},{s:.5f}s" if isinstance(s, float) else
              f"{name},{s}")

    section("Compressed resident columns (dict/FoR/RLE, lubm-like)")
    comp = bench_kernels.bench_compression(
        n=(1 << 13) if args.smoke else (1 << 15))
    report["sections"]["compression"] = comp
    for r in comp["runs"]:
        print(f"compression[{r['label']}],"
              f"resident_bytes_coded={r['resident_bytes_coded']},"
              f"checksum={r['checksum']},"
              f"codecs=for:{r['codecs']['for']}/dict:{r['codecs']['dict']}"
              f"/rle:{r['codecs']['rle']}")
    print(f"bit_identical={comp['bit_identical']},"
          f"bytes_per_fact={comp['bytes_per_fact_raw']:.2f}->"
          f"{comp['bytes_per_fact_coded']:.2f},"
          f"ratio={comp['ratio']:.2f}x")

    if not args.smoke:
        section("Extensions (paper §5): rank-N query cache + compression")
        from benchmarks import bench_extensions
        ext = []
        for label, dt, hr in bench_extensions.bench_query_cache():
            ext.append({"bench": "query-cache", "label": label,
                        "seconds": dt, "hit_rate": hr})
            print(f"query-cache,{label},{dt:.5f}s,hit_rate={hr:.2f}")
        for name, codec, ratio, enc_s in bench_extensions.bench_compression():
            ext.append({"bench": "compression", "name": name,
                        "codec": codec, "ratio": ratio, "seconds": enc_s})
            print(f"compression,{name},{codec},{ratio:.1f}x,{enc_s:.4f}s")
        report["sections"]["extensions"] = ext

        section("Roofline (from dry-run artifacts, if present)")
        from benchmarks import roofline
        for d in ("out/dryrun/single", "out/dryrun/multi"):
            if os.path.isdir(d) and os.listdir(d):
                print(f"-- {d}")
                rows = roofline.report(roofline.load(d))
                for r in rows:
                    print(f"{r['cell']},bound={r['bottleneck']},"
                          f"compute={r['compute_s']:.4f}s,"
                          f"memory={r['memory_s']:.4f}s,"
                          f"collective={r['collective_s']:.4f}s,"
                          f"useful={100*r['useful_ratio']:.1f}%,"
                          f"roofline={100*r['roofline_frac']:.2f}%")
            else:
                print(f"-- {d}: no artifacts (run repro.launch.dryrun "
                      f"first)")

    report["wall_seconds"] = time.perf_counter() - t_start
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1, default=float)
        print(f"\nwrote {args.json}")
    print(f"\nall benches done in {report['wall_seconds']:.1f}s")


if __name__ == "__main__":
    main()
