"""Hiperfact vs classic Rete, scaling curve (the headline comparison)."""

from __future__ import annotations

import time

from benchmarks.datasets import LUBM_QUERIES, lubm_like
from repro.core import EngineConfig, HiperfactEngine
from repro.core.rete_baseline import ReteEngine
from repro.core.rulesets import rdfs_plus_rules


def bench(scales=(1, 2, 4)):
    rows = []
    for s in scales:
        facts = lubm_like(s)
        e = HiperfactEngine(EngineConfig.infer1())
        e.add_rules(rdfs_plus_rules())
        e.insert_facts(facts)
        st = e.infer()
        hf = {"n_facts": len(facts), "infer_s": st.seconds,
              "inferred": st.facts_inferred}

        r = ReteEngine()
        for rr in rdfs_plus_rules():
            r.add_rule(rr)
        r.insert(facts)
        t0 = time.perf_counter()
        inferred = r.infer()
        rete_s = time.perf_counter() - t0
        rows.append((s, hf, {"infer_s": rete_s, "inferred": inferred}))
        assert hf["inferred"] == inferred, "engines disagree!"
    return rows


def main():
    print("scale,n_facts,hiperfact_infer_s,rete_infer_s,speedup,inferred")
    for s, hf, rete in bench():
        sp = rete["infer_s"] / max(hf["infer_s"], 1e-9)
        print(f"{s},{hf['n_facts']},{hf['infer_s']:.4f},"
              f"{rete['infer_s']:.4f},{sp:.1f}x,{hf['inferred']}")


if __name__ == "__main__":
    main()
