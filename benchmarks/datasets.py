"""Synthetic benchmark datasets in the style of the paper's workloads.

The paper evaluates on LUBM (scaled), WordNet, and OpenRuleBench's
Mondial/DBLP.  Those corpora are not available offline, so we generate
structurally similar synthetic data (same schema shape, same rule
stress patterns: class hierarchies, transitive properties, star joins)
with a scale knob.  Generation is seeded and deterministic.
"""

from __future__ import annotations

import numpy as np

from repro.core.conditions import cond
from repro.core.facts import Fact, ValueType


# ---------------------------------------------------------------------------
# LUBM-style (inference-heavy: RDFS-Plus over a university KG)


def lubm_like(scale: int = 1, seed: int = 0):
    """~scale x 4k facts: universities, departments, people, courses."""
    rng = np.random.RandomState(seed)
    facts = [
        Fact("Schema", "GraduateStudent", "subClassOf", "Student"),
        Fact("Schema", "Student", "subClassOf", "Person"),
        Fact("Schema", "FullProfessor", "subClassOf", "Professor"),
        Fact("Schema", "Professor", "subClassOf", "Faculty"),
        Fact("Schema", "Faculty", "subClassOf", "Employee"),
        Fact("Schema", "Employee", "subClassOf", "Person"),
        Fact("Schema", "subOrganizationOf", "characteristic", "transitive"),
        Fact("Schema", "memberOf", "domain", "Person"),
        Fact("Schema", "teacherOf", "domain", "Faculty"),
        Fact("Schema", "takesCourse", "domain", "Student"),
        Fact("Schema", "advisor", "range", "Professor"),
    ]
    n_uni = max(1, scale)
    for u in range(n_uni):
        uni = f"uni{u}"
        for d in range(8):
            dept = f"dept{u}_{d}"
            facts.append(Fact("Data", dept, "subOrganizationOf", uni))
            for g in range(2):
                grp = f"group{u}_{d}_{g}"
                facts.append(Fact("Data", grp, "subOrganizationOf", dept))
            for p in range(6):
                prof = f"prof{u}_{d}_{p}"
                facts.append(Fact("Data", prof, "type",
                                  "FullProfessor" if p % 3 == 0
                                  else "Professor"))
                facts.append(Fact("Data", prof, "memberOf", dept))
                for c in range(2):
                    facts.append(Fact("Data", prof, "teacherOf",
                                      f"course{u}_{d}_{p}_{c}"))
            for s in range(40):
                stu = f"stu{u}_{d}_{s}"
                facts.append(Fact("Data", stu, "type",
                                  "GraduateStudent" if s % 4 == 0
                                  else "Student"))
                facts.append(Fact("Data", stu, "memberOf", dept))
                facts.append(Fact("Data", stu, "advisor",
                                  f"prof{u}_{d}_{rng.randint(6)}"))
                for c in range(3):
                    facts.append(Fact(
                        "Data", stu, "takesCourse",
                        f"course{u}_{d}_{rng.randint(6)}_{rng.randint(2)}"))
    return facts


LUBM_QUERIES = [
    [cond("Data", "?x", "type", "Person")],
    [cond("Data", "?x", "type", "Student"),
     cond("Data", "?x", "takesCourse", "?c")],
    [cond("Data", "?x", "subOrganizationOf", "?u")],
    [cond("Data", "?s", "advisor", "?p"),
     cond("Data", "?p", "memberOf", "?d"),
     cond("Data", "?s", "memberOf", "?d")],
]


# ---------------------------------------------------------------------------
# WordNet-style (deep transitive hyponym chains + symmetric similarity)


def wordnet_like(n_synsets: int = 2000, seed: int = 0):
    rng = np.random.RandomState(seed)
    facts = [
        Fact("Schema", "hyponymOf", "characteristic", "transitive"),
        Fact("Schema", "similarTo", "characteristic", "symmetric"),
    ]
    # random recursive tree: expected depth ~2 ln(n) (hypernym taxonomy)
    for i in range(2, n_synsets):
        parent = rng.randint(1, i)
        facts.append(Fact("Data", f"syn{i}", "hyponymOf", f"syn{parent}"))
        if i % 7 == 0:
            facts.append(Fact("Data", f"syn{i}", "similarTo",
                              f"syn{rng.randint(1, n_synsets)}"))
    return facts


WORDNET_QUERIES = [
    [cond("Data", "?x", "hyponymOf", "syn1")],
    [cond("Data", "?a", "similarTo", "?b")],
]


# ---------------------------------------------------------------------------
# Mondial-style (query-heavy star joins; paper Fig. 6)


def mondial_like(n_countries: int = 30, cities_per: int = 60, seed: int = 0):
    rng = np.random.RandomState(seed)
    facts = []
    for c in range(n_countries):
        cc = f"cc{c}"
        for p in range(5):
            prov = f"prov{c}_{p}"
            facts.append(Fact("Province", prov, "cc", cc))
            facts.append(Fact("Province", prov, "name", f"P{c}_{p}"))
            facts.append(Fact("Province", prov, "population",
                              int(rng.randint(1e5, 1e7)), ValueType.INT64))
        for ci in range(cities_per):
            city = f"city{c}_{ci}"
            facts.append(Fact("City", city, "cc", cc))
            facts.append(Fact("City", city, "province",
                              f"P{c}_{rng.randint(5)}"))
            facts.append(Fact("City", city, "population",
                              int(rng.randint(1e3, 1e6)), ValueType.INT64))
    return facts


def mondial_queries(cc: str = "cc0"):
    return [
        # all cities with their province record in country cc (2 islands)
        [cond("City", "?x", "cc", cc),
         cond("City", "?x", "province", "?p"),
         cond("Province", "?y", "name", "?p"),
         cond("Province", "?y", "cc", cc)],
        # population join test (Def. 9): city bigger than its province? none,
        # but exercises typed comparisons
        [cond("City", "?x", "province", "?p"),
         cond("City", "?x", "population", "?cp", ValueType.INT64),
         cond("Province", "?y", "name", "?p"),
         cond("Province", "?y", "population", "?pp", ValueType.INT64,
              tests=[("?cp", "<", "?pp")])],
    ]


# ---------------------------------------------------------------------------
# DBLP-style (bibliography star joins)


def dblp_like(n_papers: int = 4000, n_authors: int = 800, seed: int = 0):
    rng = np.random.RandomState(seed)
    facts = []
    for p in range(n_papers):
        pid = f"paper{p}"
        facts.append(Fact("Paper", pid, "year",
                          int(1990 + rng.randint(30)), ValueType.INT32))
        facts.append(Fact("Paper", pid, "venue", f"venue{rng.randint(40)}"))
        for a in rng.choice(n_authors, size=rng.randint(1, 4),
                            replace=False):
            facts.append(Fact("Paper", pid, "author", f"author{a}"))
    return facts


def dblp_queries():
    return [
        # co-authorship via shared paper
        [cond("Paper", "?p", "author", "?a1"),
         cond("Paper", "?p", "author", "?a2"),
         cond("Paper", "?p", "venue", "venue1")],
        # author-year star
        [cond("Paper", "?p", "author", "author1"),
         cond("Paper", "?p", "year", "?y", ValueType.INT32)],
    ]
